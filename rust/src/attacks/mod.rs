//! Byzantine attack library.
//!
//! An [`Attack`] is what a coalition of `f` colluding Byzantine workers
//! sends to the parameter server in one round, given full knowledge of the
//! correct workers' gradients (the strongest, omniscient threat model of
//! the paper's §II-C: "the Byzantine worker is always assumed to follow
//! arbitrarily bad policies and the analysis is a worst-case one").
//!
//! Implemented attacks:
//!
//! | Attack | Reference | Breaks |
//! |---|---|---|
//! | [`SignFlip`] | classic reversed gradient | averaging |
//! | [`RandomGauss`] | noise blasting | averaging |
//! | [`Infinity`] | magnitude blow-up (also NaN mode) | averaging, naive code |
//! | [`LittleIsEnough`] | Baruch et al. 2019 [3] | weakly-resilient GARs in high d |
//! | [`Omniscient`] | El Mhamdi et al. 2018 [12] §"hidden vulnerability" | distance-based selection w/o median |
//! | [`Mimic`] | consistency attack | (selection-bias probe, convergence-safe) |
//! | [`Zero`] | stalling | progress of mean-style GARs |

mod little;
mod omniscient;
mod simple;

pub use little::LittleIsEnough;
pub use omniscient::Omniscient;
pub use simple::{Infinity, Mimic, RandomGauss, SignFlip, Zero};

use crate::tensor::GradMatrix;
use crate::Result;
use crate::util::Rng64;

/// Everything the Byzantine coalition observes in one round.
pub struct AttackCtx<'a> {
    /// Gradients of the `n − f` correct workers this round (the coalition
    /// is omniscient: it sees them before the server does).
    pub correct: &'a GradMatrix,
    /// Coalition size (number of Byzantine gradients to forge).
    pub f: usize,
    /// Total number of workers `n` (the server will see `correct.n() + f`
    /// gradients).
    pub n: usize,
}

impl<'a> AttackCtx<'a> {
    pub fn new(correct: &'a GradMatrix, f: usize, n: usize) -> Self {
        debug_assert_eq!(correct.n() + f, n);
        Self { correct, f, n }
    }

    /// Coordinate-wise mean of the correct gradients (the coalition's best
    /// estimate of the true gradient `g`).
    pub fn correct_mean(&self) -> Vec<f32> {
        self.correct.mean_rows()
    }

    /// Coordinate-wise (population) standard deviation of the correct
    /// gradients.
    pub fn correct_std(&self) -> Vec<f32> {
        let k = self.correct.n();
        let mean = self.correct_mean();
        let d = self.correct.d();
        let mut var = vec![0.0f32; d];
        for i in 0..k {
            let row = self.correct.row(i);
            for j in 0..d {
                let dev = row[j] - mean[j];
                var[j] += dev * dev;
            }
        }
        var.iter_mut().for_each(|v| *v = (*v / k as f32).sqrt());
        var
    }
}

/// A Byzantine coalition strategy: forge the `f` gradients for one round.
pub trait Attack: Send + Sync {
    /// Stable name for configs/CSV.
    fn name(&self) -> &'static str;

    /// Produce the `f × d` matrix of Byzantine proposals.
    fn forge(&self, ctx: &AttackCtx<'_>, rng: &mut Rng64) -> Result<GradMatrix>;
}

/// Config/CLI surface for attack selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AttackKind {
    None,
    SignFlip { scale: f32 },
    RandomGauss { scale: f32 },
    Infinity { nan: bool },
    /// `z`: deviation in per-coordinate std-devs; `None` derives the
    /// z_max of the original paper from (n, f).
    LittleIsEnough { z: Option<f32> },
    Omniscient { epsilon: f32 },
    Mimic,
    Zero,
}

impl AttackKind {
    /// All non-trivial attacks with default parameters (the resilience
    /// gauntlet sweep).
    pub fn gauntlet() -> Vec<AttackKind> {
        vec![
            AttackKind::SignFlip { scale: 10.0 },
            AttackKind::RandomGauss { scale: 10.0 },
            AttackKind::Infinity { nan: false },
            AttackKind::LittleIsEnough { z: None },
            AttackKind::Omniscient { epsilon: 0.1 },
            AttackKind::Mimic,
            AttackKind::Zero,
        ]
    }

    /// Instantiate the strategy. Returns `None` for `AttackKind::None`.
    pub fn instantiate(self) -> Option<Box<dyn Attack>> {
        match self {
            AttackKind::None => None,
            AttackKind::SignFlip { scale } => Some(Box::new(SignFlip::new(scale))),
            AttackKind::RandomGauss { scale } => Some(Box::new(RandomGauss::new(scale))),
            AttackKind::Infinity { nan } => Some(Box::new(Infinity::new(nan))),
            AttackKind::LittleIsEnough { z } => Some(Box::new(LittleIsEnough::new(z))),
            AttackKind::Omniscient { epsilon } => Some(Box::new(Omniscient::new(epsilon))),
            AttackKind::Mimic => Some(Box::new(Mimic)),
            AttackKind::Zero => Some(Box::new(Zero)),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            AttackKind::None => "none",
            AttackKind::SignFlip { .. } => "sign-flip",
            AttackKind::RandomGauss { .. } => "random-gauss",
            AttackKind::Infinity { .. } => "infinity",
            AttackKind::LittleIsEnough { .. } => "little-is-enough",
            AttackKind::Omniscient { .. } => "omniscient",
            AttackKind::Mimic => "mimic",
            AttackKind::Zero => "zero",
        }
    }
}

impl std::str::FromStr for AttackKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().replace('_', "-").as_str() {
            "none" => Ok(AttackKind::None),
            "sign-flip" | "signflip" => Ok(AttackKind::SignFlip { scale: 1.0 }),
            "random-gauss" | "random" | "gauss" => Ok(AttackKind::RandomGauss { scale: 10.0 }),
            "infinity" | "inf" => Ok(AttackKind::Infinity { nan: false }),
            "nan" => Ok(AttackKind::Infinity { nan: true }),
            "little-is-enough" | "lie" | "little" => Ok(AttackKind::LittleIsEnough { z: None }),
            "omniscient" | "optimal" => Ok(AttackKind::Omniscient { epsilon: 0.1 }),
            "mimic" => Ok(AttackKind::Mimic),
            "zero" => Ok(AttackKind::Zero),
            other => anyhow::bail!(
                "unknown attack '{other}' (expected: none, sign-flip, random-gauss, \
                 infinity, nan, little-is-enough, omniscient, mimic, zero)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
        #[test]
    fn ctx_mean_and_std() {
        let correct = GradMatrix::from_rows(&[vec![0.0, 2.0], vec![2.0, 2.0]]);
        let ctx = AttackCtx::new(&correct, 1, 3);
        assert_eq!(ctx.correct_mean(), vec![1.0, 2.0]);
        assert_eq!(ctx.correct_std(), vec![1.0, 0.0]);
    }

    #[test]
    fn every_gauntlet_attack_forges_f_rows() {
        let correct = GradMatrix::from_fn(9, 16, |i, j| (i as f32 * 0.1) + (j as f32 * 0.01));
        let ctx = AttackCtx::new(&correct, 2, 11);
        let mut rng = Rng64::seed_from_u64(1);
        for kind in AttackKind::gauntlet() {
            let attack = kind.instantiate().unwrap();
            let forged = attack.forge(&ctx, &mut rng).unwrap();
            assert_eq!(forged.n(), 2, "{}", attack.name());
            assert_eq!(forged.d(), 16, "{}", attack.name());
        }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!("lie".parse::<AttackKind>().unwrap().label(), "little-is-enough");
        assert_eq!("sign_flip".parse::<AttackKind>().unwrap().label(), "sign-flip");
        assert!("bogus".parse::<AttackKind>().is_err());
    }
}
