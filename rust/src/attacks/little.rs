//! "A Little Is Enough" [Baruch, Baruch & Goldberg, NeurIPS 2019 — ref [3]
//! of the paper]: the coalition shifts every coordinate by `z` standard
//! deviations of the correct gradients' empirical distribution.
//!
//! The shift per coordinate is small enough that each Byzantine vector
//! stays inside the correct cluster (so distance-based weak GARs select
//! it), yet across `d` coordinates the accumulated deviation is `z·σ·√d` —
//! exactly the `√d` leeway the paper's Fig. 1 illustrates and BULYAN's
//! median step removes. This is the canonical attack separating *weak*
//! from *strong* Byzantine resilience.

use super::{Attack, AttackCtx};
use crate::tensor::GradMatrix;
use crate::Result;
use crate::util::Rng64;

/// Coalition sends `mean(correct) − z · std(correct)` (coordinate-wise).
#[derive(Debug, Clone)]
pub struct LittleIsEnough {
    /// Explicit z; `None` derives `z_max` from the original paper's
    /// formula at forge time.
    z: Option<f32>,
}

impl LittleIsEnough {
    pub fn new(z: Option<f32>) -> Self {
        Self { z }
    }

    /// z_max of Baruch et al.: the largest shift such that the Byzantine
    /// vectors remain "inside the pack" — the normal quantile at
    /// `(n − f − s)/(n − f)` with `s = ⌊n/2⌋ + 1 − f` supporters.
    /// We use the common closed-form approximation via Acklam's inverse
    /// normal CDF.
    pub fn z_max(n: usize, f: usize) -> f32 {
        let nf = (n - f) as f64;
        let s = (n / 2 + 1).saturating_sub(f) as f64;
        let phi = ((nf - s) / nf).clamp(1e-6, 1.0 - 1e-6);
        inverse_normal_cdf(phi) as f32
    }
}

impl Attack for LittleIsEnough {
    fn name(&self) -> &'static str {
        "little-is-enough"
    }

    fn forge(&self, ctx: &AttackCtx<'_>, _rng: &mut Rng64) -> Result<GradMatrix> {
        let z = self.z.unwrap_or_else(|| Self::z_max(ctx.n, ctx.f)).max(0.0);
        let mean = ctx.correct_mean();
        let std = ctx.correct_std();
        let row: Vec<f32> = mean
            .iter()
            .zip(&std)
            .map(|(m, s)| m - z * s)
            .collect();
        Ok(GradMatrix::from_rows(&vec![row; ctx.f]))
    }
}

/// Acklam's rational approximation to the inverse normal CDF (|ε| < 1.15e-9
/// over (0,1)). Self-contained to keep the crate dependency-free.
fn inverse_normal_cdf(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -inverse_normal_cdf(1.0 - p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
        #[test]
    fn inverse_cdf_sanity() {
        assert!((inverse_normal_cdf(0.5)).abs() < 1e-9);
        assert!((inverse_normal_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((inverse_normal_cdf(0.025) + 1.959964).abs() < 1e-4);
    }

    #[test]
    fn z_max_reasonable_for_fig3_setting() {
        // n=11, f=2: s = 4, phi = 5/9 ≈ 0.556 → z ≈ 0.14.
        let z = LittleIsEnough::z_max(11, 2);
        assert!(z > 0.0 && z < 1.0, "z={z}");
    }

    #[test]
    fn forged_vector_stays_near_the_pack() {
        // With z=1, every coordinate deviates by exactly one empirical σ.
        let correct = GradMatrix::from_rows(&[
            vec![0.0, 10.0],
            vec![2.0, 10.0],
        ]);
        let ctx = AttackCtx::new(&correct, 1, 3);
        let mut rng = Rng64::seed_from_u64(0);
        let forged = LittleIsEnough::new(Some(1.0)).forge(&ctx, &mut rng).unwrap();
        // mean = [1, 10], std = [1, 0] → forged = [0, 10]
        assert_eq!(forged.row(0), &[0.0, 10.0]);
    }
}
