//! `multibulyan` — launcher CLI (hand-rolled argument parsing; the offline
//! build has no clap).
//!
//! ```text
//! multibulyan train [--config FILE] [--gar G] [--attack A] [--n N] [--f F]
//!                   [--byzantine B] [--model M] [--steps S] [--batch-size B]
//!                   [--lr LR] [--momentum MU] [--eval-every K] [--seed S]
//!                   [--transport threaded|pooled|socket] [--codec C]
//!                   [--socket-listen ADDR] [--socket-chunk K]
//!                   [--artifacts DIR] [--curve-out FILE]
//! multibulyan worker --connect ADDR --worker-id K [--dim D] [--noise X]
//!                   [--seed S] [--batch-size B] [--chunk K] [--codec C]
//! multibulyan aggregate [--gar G] [--n N] [--f F] [--dim D]
//! multibulyan bench <fig2|fig3|dscaling|dscale|slowdown|resilience|codec|cone>
//!                   [--full] [--artifacts DIR]
//! multibulyan bench check [--baseline FILE] [--tolerance X] [--update]
//! multibulyan artifacts-check [--artifacts DIR]
//! ```

use multibulyan::attacks::AttackKind;
use multibulyan::bench;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::launch;
use multibulyan::gar::{GarKind, GarSpec};
use multibulyan::runtime::{ComputeServer, Manifest};
use multibulyan::tensor::GradMatrix;
use multibulyan::util::Rng64;
use multibulyan::Result;

/// Minimal flag parser: `--key value` pairs plus positional arguments.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags: --full (no value or next is a flag)
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Ok(Self { positional, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, dflt: &str) -> String {
        self.get(key).unwrap_or(dflt).to_string()
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, dflt: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(dflt),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

const USAGE: &str = "\
multibulyan — MULTI-KRUM / MULTI-BULYAN Byzantine-resilient distributed SGD

USAGE:
  multibulyan train [--config FILE] [--gar G] [--attack A] [--n N] [--f F]
                    [--byzantine B] [--model quadratic|mlp|cnn|transformer]
                    [--steps S] [--batch-size B] [--lr LR] [--momentum MU]
                    [--eval-every K] [--seed S] [--threads T]
                    [--transport threaded|pooled|socket] [--collect first-m|all]
                    [--overlap off|prefix] [--overlap-window W] [--groups G]
                    [--codec off|raw|lossless|fp16|int8|topk]
                    [--params-checksum]
                    [--journal FILE] [--crash-after-round R]
                    [--churn-leave-round R] [--churn-workers W]
                    [--churn-rejoin-round R]
                    [--socket-listen ADDR] [--socket-chunk K]
                    [--artifacts DIR] [--curve-out FILE]
  multibulyan worker --connect ADDR --worker-id K [--dim D] [--noise X]
                    [--seed S] [--batch-size B] [--chunk K]
                    [--codec off|raw|lossless|fp16|int8|topk] [--retry-ms MS]
                    [--rejoin]
  multibulyan aggregate [--gar G] [--n N] [--f F] [--dim D] [--threads T]
  multibulyan bench <fig2|fig3|dscaling|dscale|slowdown|threads|straggler
                     |resilience|codec|cone> [--full] [--artifacts DIR]
  multibulyan bench check [--baseline FILE] [--tolerance X] [--update]
  multibulyan artifacts-check [--artifacts DIR]
  multibulyan lint [--root DIR] [--list]

GARs:    average median trimmed-mean krum multi-krum bulyan multi-bulyan
         --gar also accepts a pre-aggregation pipeline spec:
         (stage+)*rule, stage = rmom(beta) with beta in [0,1) — e.g.
         --gar 'rmom(0.9)+multi-bulyan' aggregates resilient momentums
         (train command; `aggregate` times the bare rule only)
Attacks: none sign-flip random-gauss infinity nan little-is-enough
         omniscient mimic zero
Threads: --threads 1 (sequential, default) | 0 (auto) | N (shared pool);
         aggregation output is bit-identical for every setting
Transport: --transport pooled (default; logical workers multiplexed over
         the shared pool — scales to 100+ workers) | threaded (one OS
         thread per worker) | socket (the wire transport of
         docs/wire-protocol.md over TCP or Unix sockets; workers are
         in-process client threads by default, or external
         `multibulyan worker` processes when --socket-listen is given);
         seeded runs are bit-identical on all three
Socket:  --socket-listen tcp:HOST:PORT | unix:PATH | HOST:PORT (the
         coordinator's bind address; implies external worker processes —
         start one `multibulyan worker --connect ADDR --worker-id K` per
         honest worker with matching --dim/--noise/--seed/--batch-size)
         --socket-chunk K streams gradients in K-coordinate GradientChunk
         frames (default 16384) so no full d-length send buffer exists
Collect: --collect all (default; wait for every honest worker up to the
         round timeout) | first-m (the paper's synchronous model —
         proceed at the fastest m = n − f gradients; stragglers fall
         through the last-good cache)
Overlap: --overlap off (default; collect, then select, then combine) |
         prefix (streaming prefix-combine: select at the first-m quorum
         and interleave the combine+update chunks with the remaining
         drive slices on the pooled transport; each round is
         bit-identical to off, and a straggler finishing inside the
         overlap window is salvaged into the last-good cache — a
         fresher fallback for later rounds than off's older-or-zero row)
         --overlap-window W claims W combine chunks per drive slice
         (default 1 — the longest late-acceptance window; any value is
         bit-identical, the knob only paces the prefix tail)
         --params-checksum prints an FNV-1a digest of the final
         parameters (the CI determinism-matrix probe)
Groups:  --groups G (default 1 = flat) partitions the n workers into G
         groups; gradients stream-reduce group-wise in 4096-coordinate
         blocks (no n×d matrix is ever materialized) and the GAR runs
         over the G group rows with the scaled Byzantine bound
         f_root = ceil(f·G/n). Requires --collect all, --overlap off
         and --codec off; --groups 1 is bit-identical to omitting the
         flag. Equivalent spelling: a leading group(G) pipeline stage,
         e.g. --gar 'group(8)+trimmed-mean'. `bench dscale` sweeps the
         grouped end-to-end round to d = 10^7 and gates the fitted
         log-log slope on linearity (the CI memory/scaling probe)
Codec:   --codec off (default; raw f32 gradient frames) | raw (identity
         encoding through the codec path — bit-identical to off) |
         lossless (byte-shuffle + RLE, bit-exact) | fp16 | int8 (per-block
         quantization) | topk (top-k sparsification with per-worker error
         feedback). Lossy codecs trade gradient fidelity for bytes on the
         wire — see `bench codec` and docs/wire-protocol.md §7. The
         worker command's --codec must be accepted by the coordinator
         (Hello capability negotiation); unknown names are rejected
         up front with the valid list
Journal: --journal FILE appends one checksummed record per committed
         round (params digest, selection, membership view, metrics) to
         an append-only round-journal, fsync'd before the round is
         reported. Re-running with the same --journal resumes from the
         last committed round by verified deterministic replay —
         bit-identical to an uninterrupted run (the CI crash-recovery
         probe diffs --params-checksum across the two). A torn tail
         (crash mid-write) is truncated on open; a corrupt committed
         record is a hard error. --crash-after-round R aborts the
         process right after committing round R (fault injection for
         the recovery leg; requires --journal)
Churn:   --churn-leave-round R drops the first --churn-workers W honest
         workers from the membership view at round R (1-based); they
         rejoin at --churn-rejoin-round (0 = never). Each view change
         revalidates the GAR quorum, re-shards the data assignment and
         re-instantiates the rule at the shrunken size; flat path only
         (--groups 1). External socket workers leave live instead: a
         Goodbye frame or crash shrinks the next view, and a worker
         process restarted with --rejoin reclaims its slot
         (docs/wire-protocol.md §8)
Lint:    `lint` runs the repo-specific invariant linter over rust/src,
         rust/tests and examples/ (unsafe audit, wall-clock, pool-only
         parallelism, hash-iteration, float-reduction rules); exits
         nonzero on findings. --list prints the rule catalog.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" {
        print!("{USAGE}");
        std::process::exit(if argv.is_empty() { 2 } else { 0 });
    }
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "train" => cmd_train(&args),
        "worker" => cmd_worker(&args),
        "aggregate" => cmd_aggregate(&args),
        "bench" => cmd_bench(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "lint" => cmd_lint(&args),
        other => anyhow::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let exp = match args.get("config") {
        Some(path) => ExperimentConfig::from_path(path)?,
        None => {
            let gar_spec: GarSpec = args.get_or("gar", "multi-bulyan").parse()?;
            let attack: AttackKind = args.get_or("attack", "none").parse()?;
            let n: usize = args.parse_or("n", 11)?;
            let f: usize = args.parse_or("f", 2)?;
            let byz = match args.get("byzantine") {
                Some(v) => v.parse()?,
                None => {
                    if attack == AttackKind::None {
                        0
                    } else {
                        f
                    }
                }
            };
            let model = args.get_or("model", "quadratic");
            ExperimentConfig {
                cluster: ClusterConfig {
                    n,
                    f,
                    actual_byzantine: Some(byz),
                    net_delay_us: 0,
                    drop_prob: 0.0,
                    round_timeout_ms: 60_000,
                    ..Default::default()
                },
                gar: gar_spec.kind,
                pre: gar_spec.stages,
                attack,
                model: if model == "quadratic" {
                    ModelConfig::Quadratic {
                        dim: args.parse_or("dim", 1000)?,
                        noise: 0.5,
                    }
                } else {
                    ModelConfig::Artifact {
                        name: model.clone(),
                        dir: args.get_or("artifacts", "artifacts"),
                    }
                },
                train: TrainConfig {
                    learning_rate: args.parse_or("lr", 0.1)?,
                    momentum: args.parse_or("momentum", 0.9)?,
                    steps: args.parse_or("steps", 300)?,
                    batch_size: args.parse_or("batch-size", 25)?,
                    eval_every: args.parse_or("eval-every", 50)?,
                    seed: args.parse_or("seed", 1)?,
                },
                // Defaults; the shared --threads / --transport overrides
                // below apply whenever the flags are present.
                threads: 1,
                transport: Default::default(),
                collect: Default::default(),
                overlap: Default::default(),
                overlap_window: 1,
                codec: None,
                groups: 1,
                output_dir: None,
                journal: None,
                crash_after_round: None,
            }
        }
    };
    let mut exp = exp;
    if let Some(t) = args.get("threads") {
        exp.threads = t
            .parse()
            .map_err(|e| anyhow::anyhow!("--threads {t}: {e}"))?;
    }
    if let Some(t) = args.get("transport") {
        exp.transport = t.parse()?;
    }
    if let Some(c) = args.get("collect") {
        exp.collect = c.parse()?;
    }
    if let Some(o) = args.get("overlap") {
        exp.overlap = o.parse()?;
    }
    if let Some(w) = args.get("overlap-window") {
        exp.overlap_window = w
            .parse()
            .map_err(|e| anyhow::anyhow!("--overlap-window {w}: {e}"))?;
    }
    if let Some(c) = args.get("codec") {
        exp.codec = match c {
            "off" => None,
            _ => Some(c.parse()?),
        };
    }
    if let Some(g) = args.get("groups") {
        exp.groups = g
            .parse()
            .map_err(|e| anyhow::anyhow!("--groups {g}: {e}"))?;
    }
    if let Some(addr) = args.get("socket-listen") {
        exp.cluster.socket_listen = Some(addr.to_string());
    }
    if let Some(c) = args.get("socket-chunk") {
        exp.cluster.socket_chunk = c
            .parse()
            .map_err(|e| anyhow::anyhow!("--socket-chunk {c}: {e}"))?;
    }
    if let Some(p) = args.get("journal") {
        exp.journal = Some(p.to_string());
    }
    if args.has("crash-after-round") {
        exp.crash_after_round = Some(args.parse_or("crash-after-round", 0u64)?);
    }
    if let Some(r) = args.get("churn-leave-round") {
        exp.cluster.churn_leave_round = r
            .parse()
            .map_err(|e| anyhow::anyhow!("--churn-leave-round {r}: {e}"))?;
    }
    if let Some(w) = args.get("churn-workers") {
        exp.cluster.churn_workers = w
            .parse()
            .map_err(|e| anyhow::anyhow!("--churn-workers {w}: {e}"))?;
    }
    if let Some(r) = args.get("churn-rejoin-round") {
        exp.cluster.churn_rejoin_round = r
            .parse()
            .map_err(|e| anyhow::anyhow!("--churn-rejoin-round {r}: {e}"))?;
    }
    exp.validate()?;
    let compute = match &exp.model {
        ModelConfig::Artifact { dir, .. } => {
            let manifest = Manifest::load(dir)?;
            let server = ComputeServer::start(manifest.clone())?;
            Some((server, manifest))
        }
        _ => None,
    };
    let handle = compute.as_ref().map(|(s, m)| (s.handle(), m.clone()));
    println!(
        "training: gar={} attack={} n={} f={} byz={} steps={} b={} transport={} collect={} \
         overlap={} codec={}",
        exp.gar_spec(),
        exp.attack.label(),
        exp.cluster.n,
        exp.cluster.f,
        exp.byzantine_count(),
        exp.train.steps,
        exp.train.batch_size,
        exp.transport,
        exp.collect,
        exp.overlap,
        exp.codec.map_or("off", |c| c.as_str())
    );
    let cluster = launch(&exp, handle)?;
    let mut coordinator = cluster.coordinator;
    let mut evaluator = cluster.evaluator;
    coordinator.train(exp.train.steps, exp.train.eval_every, &mut evaluator)?;
    println!("{}", coordinator.metrics.summary());
    for p in coordinator.metrics.curve() {
        println!(
            "  step {:>6}  loss {:>10.5}  acc {:>7.4}",
            p.step, p.loss, p.accuracy
        );
    }
    if let Some(path) = args.get("curve-out") {
        coordinator.metrics.write_curve_csv(path)?;
        println!("curve written to {path}");
    }
    if args.has("params-checksum") {
        // FNV-1a over the little-endian parameter bits: the determinism
        // matrix in CI diffs this digest across transport × threads ×
        // overlap legs of the same seeded run.
        let digest = multibulyan::util::fnv1a(
            coordinator
                .params()
                .iter()
                .flat_map(|v| v.to_le_bytes()),
        );
        println!("params_checksum=0x{digest:016x}");
    }
    coordinator.shutdown();
    Ok(())
}

fn cmd_worker(args: &Args) -> Result<()> {
    use multibulyan::data::QuadraticProblem;
    use multibulyan::transport::socket;
    use multibulyan::worker::{GradSource, GradWorker};
    use std::sync::Arc;

    let addr = args.get("connect").ok_or_else(|| {
        anyhow::anyhow!("worker: --connect ADDR is required (tcp:HOST:PORT | unix:PATH | HOST:PORT)")
    })?;
    let worker_id: usize = args
        .get("worker-id")
        .ok_or_else(|| {
            anyhow::anyhow!("worker: --worker-id K is required (0-based honest worker index)")
        })?
        .parse()
        .map_err(|e| anyhow::anyhow!("--worker-id: {e}"))?;
    let dim: usize = args.parse_or("dim", 1000)?;
    let noise: f32 = args.parse_or("noise", 0.5)?;
    let seed: u64 = args.parse_or("seed", 1)?;
    let batch_size: usize = args.parse_or("batch-size", 25)?;
    let chunk: usize = args.parse_or("chunk", socket::DEFAULT_CHUNK)?;
    let retry_ms: u64 = args.parse_or("retry-ms", 5_000)?;
    // A restarted worker process reclaims its slot: the rejoin bit in the
    // Hello flags byte tells the coordinator to evict the dead incumbent
    // connection instead of rejecting the duplicate (wire spec §8).
    let rejoin = args.has("rejoin");
    anyhow::ensure!(chunk >= 1, "--chunk must be ≥ 1");
    let codec = match args.get("codec") {
        None | Some("off") => None,
        Some(name) => Some(name.parse::<multibulyan::codec::CodecKind>()?),
    };

    // Mirror the coordinator's problem construction (ModelConfig::Quadratic
    // + train.seed in coordinator::launch): gradients are counter-seeded
    // from (dim, noise, seed, worker, round), so matching flags make this
    // process bit-identical to an in-process worker thread.
    let problem = Arc::new(QuadraticProblem::new(dim, noise, seed));
    let source = GradSource::quadratic(problem, worker_id, batch_size);

    // The coordinator may still be binding its listener (the
    // examples/socket_cluster.sh startup race); retry with bounded
    // exponential backoff — 50 ms doubling to a 2 s cap — until roughly
    // --retry-ms total has elapsed, then give up with the last error.
    let mut waited = 0u64;
    let mut backoff_ms = 50u64;
    let client = loop {
        match socket::connect_opts(addr, worker_id, chunk, codec.unwrap_or_default(), rejoin) {
            Ok(c) => break c,
            Err(e) if waited >= retry_ms => {
                anyhow::bail!(
                    "worker {worker_id}: cannot connect to {addr} \
                     after {waited} ms of retries: {e:#}"
                )
            }
            Err(_) => {
                let sleep_ms = backoff_ms.min(retry_ms.saturating_sub(waited).max(1));
                std::thread::sleep(std::time::Duration::from_millis(sleep_ms));
                waited += sleep_ms;
                backoff_ms = (backoff_ms * 2).min(2_000);
            }
        }
    };
    eprintln!(
        "worker {worker_id}: connected to {addr} (dim={dim} chunk={chunk} codec={})",
        codec.unwrap_or_default().as_str()
    );
    client.run_streaming(GradWorker::with_codec(source, codec))
}

fn cmd_aggregate(args: &Args) -> Result<()> {
    let kind: GarKind = args.get_or("gar", "multi-bulyan").parse()?;
    let n: usize = args.parse_or("n", 11)?;
    let f: usize = args.parse_or("f", 2)?;
    let dim: usize = args.parse_or("dim", 100_000)?;
    let threads: usize = args.parse_or("threads", 1)?;
    anyhow::ensure!(
        threads <= multibulyan::config::MAX_THREADS,
        "--threads must be ≤ {} (0 = auto, 1 = sequential), got {threads}",
        multibulyan::config::MAX_THREADS
    );
    let par = multibulyan::runtime::Parallelism::new(threads);
    let rule = kind.instantiate_parallel(n, f, &par)?;
    let mut rng = Rng64::seed_from_u64(0);
    let grads = GradMatrix::uniform(n, dim, 0.0, 1.0, &mut rng);
    let sw = multibulyan::metrics::Stopwatch::start();
    let out = rule.aggregate(&grads)?;
    println!(
        "{} over {}×{} gradients: {:.3} ms (‖out‖ = {:.4})",
        rule.name(),
        n,
        dim,
        sw.elapsed_ms(),
        multibulyan::tensor::l2_norm(&out)
    );
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("bench: which figure? {USAGE}"))?;
    let full = args.has("full");
    let artifacts = args.get_or("artifacts", "artifacts");
    match which.as_str() {
        "fig2" => {
            let cfg = if full {
                bench::fig2::Fig2Config::full_grid()
            } else {
                bench::fig2::Fig2Config::default_grid()
            };
            bench::fig2::run(&cfg, false)?;
        }
        "fig3" => {
            let manifest = Manifest::load(&artifacts)?;
            let server = ComputeServer::start(manifest.clone())?;
            let cfg = if full {
                bench::fig3::Fig3Config::full_sweep()
            } else {
                bench::fig3::Fig3Config::default_sweep()
            };
            bench::fig3::run(&cfg, server.handle(), &manifest, false)?;
        }
        "dscaling" => {
            // Keep every point DRAM-resident at n=15 so the log-log fit
            // measures the algorithm, not the cache hierarchy.
            let dims: Vec<usize> = if full {
                vec![300_000, 1_000_000, 3_000_000, 10_000_000]
            } else {
                vec![300_000, 1_000_000, 3_000_000]
            };
            bench::dscaling::run(
                15,
                &dims,
                &[
                    GarKind::Average,
                    GarKind::Median,
                    GarKind::MultiKrum,
                    GarKind::MultiBulyan,
                ],
                false,
            )?;
        }
        "dscale" => {
            // End-to-end grouped-collection d-sweep: one streamed round
            // per dimension through the full coordinator stack, with the
            // fitted log-log slope gated on linearity (the O(d) curve the
            // two-level hierarchy promises). --full extends to d = 10^7.
            let cfg = if full {
                bench::dscaling::DscaleConfig::full_sweep()
            } else {
                bench::dscaling::DscaleConfig::default_sweep()
            };
            bench::dscaling::run_dscale(&cfg, false)?;
        }
        "slowdown" => {
            let cfg = bench::slowdown::SlowdownConfig::default();
            bench::slowdown::run(&cfg, false)?;
        }
        "threads" => {
            // Thread-scaling of the aggregation hot path (the ROADMAP
            // "hot path measurably faster" item). d ∈ {1e5, 1e6} per the
            // acceptance grid; --full adds the paper-scale 1e7.
            let dims: Vec<usize> = if full {
                vec![100_000, 1_000_000, 10_000_000]
            } else {
                vec![100_000, 1_000_000]
            };
            let threads = [1usize, 2, 4, 8];
            bench::slowdown::thread_sweep(
                11,
                2,
                &dims,
                &threads,
                &[GarKind::MultiKrum, GarKind::MultiBulyan, GarKind::Median],
                multibulyan::metrics::TimingProtocol::default(),
                false,
                true,
            )?;
        }
        "check" => {
            // The CI perf-baseline gate: run the fixed sweep, compare
            // against the committed baseline, exit nonzero on regression.
            let path = args.get_or("baseline", "BENCH_baseline.json");
            if args.has("update") {
                bench::baseline::update(&path)?;
            } else {
                let tolerance = match args.get("tolerance") {
                    Some(t) => Some(
                        t.parse::<f64>()
                            .map_err(|e| anyhow::anyhow!("--tolerance {t}: {e}"))?,
                    ),
                    None => None,
                };
                let outcome = bench::baseline::check(&path, tolerance)?;
                outcome.bail_on_failure()?;
            }
        }
        "straggler" => {
            // First-m vs wait-all round-tail latency under the
            // deterministic straggler cost model, on both transports.
            let mut cfg = bench::straggler::StragglerConfig::default();
            if full {
                cfg.n = 128;
                cfg.f = 24;
                cfg.stragglers = 8;
                cfg.rounds = 40;
            }
            bench::straggler::run(&cfg, false)?;
        }
        "resilience" => {
            let cfg = bench::resilience::GauntletConfig::default();
            bench::resilience::run(&cfg, false)?;
        }
        "codec" => {
            // Codec × GAR × attack sweep: bytes/round, encode/decode µs,
            // rounds-to-target-loss and selection precision/recall per
            // wire codec; --full widens the grid to the whole gauntlet.
            let mut cfg = bench::codec::CodecBenchConfig::default();
            if full {
                cfg.attacks = {
                    let mut a = vec![multibulyan::attacks::AttackKind::None];
                    a.extend(multibulyan::attacks::AttackKind::gauntlet());
                    a
                };
            }
            bench::codec::run(&cfg, false)?;
        }
        "cone" => {
            let cfg = bench::cone::ConeConfig::default();
            bench::cone::run(&cfg, false)?;
        }
        other => anyhow::bail!(
            "unknown bench '{other}' \
             (fig2|fig3|dscaling|dscale|slowdown|threads|straggler|resilience|codec|cone|check)"
        ),
    }
    Ok(())
}

fn cmd_lint(args: &Args) -> Result<()> {
    use multibulyan::lint;
    if args.has("list") {
        println!("multibulyan lint — rule catalog:");
        for rule in lint::rules::RULES {
            println!("  {:<13} {}", rule.id, rule.summary);
            println!("  {:<13}   escape: {}", "", rule.escape);
        }
        return Ok(());
    }
    let root = args.get_or("root", ".");
    let report = lint::lint_repo(std::path::Path::new(&root))?;
    // Zero files means the walk missed the tree entirely (wrong --root),
    // which must not masquerade as a clean pass.
    anyhow::ensure!(
        report.files_scanned > 0,
        "lint: no .rs files found under {root:?} (expected {:?}) — wrong --root?",
        lint::LINT_DIRS
    );
    for finding in &report.findings {
        eprintln!("{finding}");
    }
    anyhow::ensure!(
        report.is_clean(),
        "lint: {} finding(s) in {} file(s) scanned",
        report.findings.len(),
        report.files_scanned
    );
    println!(
        "lint: OK — {} files, {} rules, 0 findings",
        report.files_scanned,
        lint::rules::RULES.len()
    );
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> Result<()> {
    let artifacts = args.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(&artifacts)?;
    println!(
        "manifest OK: {} artifacts, {} models",
        manifest.artifacts.len(),
        manifest.models.len()
    );
    let server = ComputeServer::start(manifest.clone())?;
    let handle = server.handle();
    for name in manifest.artifacts.keys() {
        handle.warmup(name)?;
        println!("  compiled {name}");
    }
    println!("all artifacts compile");
    Ok(())
}
