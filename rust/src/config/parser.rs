//! Minimal TOML-subset parser for experiment configs (the offline build
//! has no `toml` crate). Supported grammar — exactly what the config
//! surface needs:
//!
//! ```toml
//! # comments
//! key = "string"        # strings (double-quoted, \" \\ escapes)
//! key = 42              # integers
//! key = 0.5             # floats
//! key = true            # booleans
//! [section]             # single-level sections
//! key = 1
//! ```
//!
//! Scalar strings carry their own sub-grammars one level up; the notable
//! one is the top-level `gar` key, which accepts the aggregation-pipeline
//! spec parsed by [`crate::gar::GarSpec`]:
//!
//! ```text
//! gar   = "<spec>"
//! spec  := (stage "+")* rule
//! stage := "rmom(" beta ")"      # resilient momentum, beta ∈ [0, 1)
//! rule  := average | median | trimmed-mean | krum | multi-krum
//!        | bulyan | multi-bulyan
//! ```
//!
//! e.g. `gar = "multi-bulyan"` or `gar = "rmom(0.9)+multi-bulyan"`. This
//! module only delivers the string; splitting it into stages + terminal
//! rule happens in `config::ExperimentConfig::from_document`.

use crate::Result;
use std::collections::BTreeMap;

/// A parsed scalar.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        anyhow::ensure!(
            n >= 0.0 && n.fract() == 0.0 && n <= 9e15,
            "expected non-negative integer, got {n}"
        );
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => anyhow::bail!("expected bool, got {other:?}"),
        }
    }
}

/// Sections → keys → values. Top-level keys live under the `""` section.
pub type Document = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a config document.
pub fn parse(text: &str) -> Result<Document> {
    let mut doc: Document = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {}: unterminated section", lineno + 1))?
                .trim();
            anyhow::ensure!(
                !name.is_empty() && name.chars().all(|c| c.is_alphanumeric() || c == '-' || c == '_' || c == '.'),
                "line {}: bad section name '{name}'",
                lineno + 1
            );
            section = name.to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line.split_once('=').ok_or_else(|| {
            anyhow::anyhow!("line {}: expected 'key = value', got '{line}'", lineno + 1)
        })?;
        let key = key.trim();
        anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
        let value = parse_value(value.trim())
            .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
        doc.entry(section.clone())
            .or_default()
            .insert(key.to_string(), value);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside a string starts a comment.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value> {
    anyhow::ensure!(!text.is_empty(), "missing value");
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = text.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string"))?;
        let mut s = String::new();
        let mut chars = body.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    other => anyhow::bail!("bad escape '\\{other:?}'"),
                }
            } else if c == '"' {
                anyhow::bail!("unescaped quote inside string");
            } else {
                s.push(c);
            }
        }
        return Ok(Value::Str(s));
    }
    // Numbers (allow underscores like 10_000).
    let cleaned = text.replace('_', "");
    cleaned
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow::anyhow!("cannot parse value '{text}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_scalars() {
        let doc = parse(
            r#"
            # experiment
            gar = "multi-krum"
            verbose = true
            [cluster]
            n = 11
            f = 2
            drop_prob = 0.25   # inline comment
            [train]
            steps = 10_000
            "#,
        )
        .unwrap();
        assert_eq!(doc[""]["gar"], Value::Str("multi-krum".into()));
        assert_eq!(doc[""]["verbose"], Value::Bool(true));
        assert_eq!(doc["cluster"]["n"].as_usize().unwrap(), 11);
        assert_eq!(doc["cluster"]["drop_prob"].as_f64().unwrap(), 0.25);
        assert_eq!(doc["train"]["steps"].as_usize().unwrap(), 10_000);
    }

    #[test]
    fn string_escapes_and_hash_in_string() {
        let doc = parse(r#"msg = "a # not comment \"quoted\" \n""#).unwrap();
        assert_eq!(
            doc[""]["msg"].as_str().unwrap(),
            "a # not comment \"quoted\" \n"
        );
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"));
        let err = parse("[unterminated\n").unwrap_err();
        assert!(err.to_string().contains("line 1"));
    }

    #[test]
    fn type_accessors_guard() {
        let doc = parse("x = 1.5\ny = \"s\"\n").unwrap();
        assert!(doc[""]["x"].as_str().is_err());
        assert!(doc[""]["x"].as_usize().is_err());
        assert!(doc[""]["y"].as_f64().is_err());
        assert_eq!(doc[""]["x"].as_f32().unwrap(), 1.5);
    }
}
