//! Configuration system: TOML-subset files + CLI overrides.
//!
//! A single [`ExperimentConfig`] describes one distributed-training run —
//! cluster shape `(n, f)`, GAR, attack, model/workload, optimizer and
//! schedule — and is consumed by the launcher (`main.rs`), the bench
//! harnesses and the examples. `validate()` enforces the paper's
//! resilience preconditions (e.g. MULTI-BULYAN needs `n ≥ 4f+3`) before
//! any worker is spawned.
//!
//! File format: the TOML subset of [`parser`] —
//!
//! ```toml
//! gar = "multi-bulyan"   # or a pipeline spec: "rmom(0.9)+multi-bulyan"
//! attack = "little-is-enough"
//! [cluster]
//! n = 11
//! f = 2
//! [model]
//! kind = "quadratic"     # or "mlp" / "cnn" / "transformer" (artifacts)
//! dim = 1000
//! [train]
//! steps = 600
//! batch_size = 25
//! ```
//!
//! The `gar` key accepts the full pipeline grammar of
//! [`crate::gar::GarSpec`]: `(stage "+")* gar`, where the only stage so
//! far is `rmom(beta)` (resilient momentum, `beta ∈ [0, 1)`); the parsed
//! stages land in [`ExperimentConfig::pre`] and the terminal rule in
//! [`ExperimentConfig::gar`].

pub mod parser;

use crate::attacks::AttackKind;
use crate::coordinator::OverlapMode;
use crate::gar::{GarKind, GarSpec, StageSpec};
use crate::transport::{CollectMode, TransportKind};
use crate::Result;
use parser::Document;
use std::path::Path;

/// Default server-side round timeout (generous: PJRT gradient computation
/// on CPU can take seconds for large models/batches).
pub fn default_round_timeout_ms() -> u64 {
    60_000
}

/// Upper bound on the `threads` knob (0 = auto, 1 = sequential) — shared
/// by config validation and the CLI paths that build a pool directly.
pub const MAX_THREADS: usize = 1024;

/// Cluster shape: the `(n, f)` contract of §II-C-c.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Total number of workers.
    pub n: usize,
    /// Declared number of tolerated Byzantine workers (the contract).
    pub f: usize,
    /// Actual number of Byzantine workers simulated (≤ f for an honest
    /// adversary model; > f to demonstrate contract violation).
    pub actual_byzantine: Option<usize>,
    /// Simulated per-message network delay in microseconds (mean).
    pub net_delay_us: u64,
    /// Probability of dropping a worker's gradient in a round (the server
    /// then falls back to the round-timeout path).
    pub drop_prob: f64,
    /// Round collection timeout in milliseconds (how long the server
    /// waits for stragglers before the last-known-gradient fallback).
    /// Honoured by both transports: wall-clock on `threaded`, virtual
    /// time under the pooled backend's time-sliced drive — a worker
    /// whose simulated compute cost exceeds the timeout
    /// deterministically misses the round (see the `transport` module
    /// docs on straggler semantics).
    pub round_timeout_ms: u64,
    /// Baseline simulated per-round compute cost per worker in
    /// microseconds (the straggler model; 0 disables it). Virtual time
    /// on the pooled transport, a real pre-compute sleep on threaded.
    pub compute_cost_us: u64,
    /// Number of straggler workers (the first `stragglers` worker ids
    /// cost `compute_cost_us × straggler_factor` per round).
    pub stragglers: usize,
    /// Cost multiplier for stragglers (≥ 1).
    pub straggler_factor: f64,
    /// Socket transport only: explicit listen address
    /// (`tcp:HOST:PORT`, `unix:PATH`, or bare `HOST:PORT`). `Some` means
    /// worker slots are owned by external `multibulyan worker` processes
    /// connecting to this address; `None` (default) binds an ephemeral
    /// loopback port and serves the workers as in-process client
    /// threads. Ignored by the in-process transports.
    pub socket_listen: Option<String>,
    /// Socket transport only: GradientChunk size in f32 coordinates —
    /// workers stream gradients in pieces of this many values instead of
    /// materializing full d-length send buffers (wire spec §4.3).
    pub socket_chunk: usize,
    /// Scripted churn: round (1-based) at which the first
    /// `churn_workers` honest workers leave the cluster. 0 (default)
    /// disables churn. The coordinator shrinks the membership view,
    /// re-shards the data assignment and re-instantiates the GAR at the
    /// reduced size (quorum permitting — see `validate()`).
    pub churn_leave_round: u64,
    /// Scripted churn: how many honest workers (ids `0..churn_workers`)
    /// leave at `churn_leave_round`.
    pub churn_workers: usize,
    /// Scripted churn: round at which the departed workers rejoin.
    /// 0 = never. Must be > `churn_leave_round` when set.
    pub churn_rejoin_round: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            n: 1,
            f: 0,
            actual_byzantine: None,
            net_delay_us: 0,
            drop_prob: 0.0,
            round_timeout_ms: default_round_timeout_ms(),
            compute_cost_us: 0,
            stragglers: 0,
            straggler_factor: 1.0,
            socket_listen: None,
            socket_chunk: crate::transport::socket::DEFAULT_CHUNK,
            churn_leave_round: 0,
            churn_workers: 0,
            churn_rejoin_round: 0,
        }
    }
}

impl ClusterConfig {
    /// Raw count; `None` is resolved at the experiment level (where the
    /// attack is known) by [`ExperimentConfig::byzantine_count`].
    pub fn byzantine_count_or(&self, default: usize) -> usize {
        self.actual_byzantine.unwrap_or(default)
    }
}

/// Which model/workload the workers compute gradients for.
#[derive(Debug, Clone, PartialEq)]
pub enum ModelConfig {
    /// Rust-native synthetic least-squares problem (no PJRT needed):
    /// workers hold shards of a linear-regression-style dataset. Used by
    /// unit tests and the fast ablation benches.
    Quadratic { dim: usize, noise: f32 },
    /// AOT-compiled JAX model executed via PJRT; `name` selects the
    /// artifact family from `artifacts/manifest.json` (e.g. "mlp",
    /// "cnn", "transformer").
    Artifact { name: String, dir: String },
}

/// Optimizer + schedule (the paper's Fig. 3 protocol: lr 0.1, momentum
/// 0.9, 3000 steps).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub learning_rate: f32,
    pub momentum: f32,
    pub steps: usize,
    /// Per-worker minibatch size (Fig. 3 sweeps 5..=50).
    pub batch_size: usize,
    /// Evaluate accuracy/loss every `eval_every` steps (0 = only at end).
    pub eval_every: usize,
    /// RNG seed (Fig. 3 uses seeds 1..=5).
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            learning_rate: 0.1,
            momentum: 0.9,
            steps: 600,
            batch_size: 25,
            eval_every: 100,
            seed: 1,
        }
    }
}

/// The full experiment description.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub gar: GarKind,
    /// Pre-aggregation stages applied before `gar`'s selection phase, in
    /// order — the `rmom(0.9)+multi-bulyan` pipeline spec (`gar` key /
    /// `--gar` flag; see `crate::gar::pipeline`). Empty = plain GAR.
    pub pre: Vec<StageSpec>,
    pub attack: AttackKind,
    pub model: ModelConfig,
    pub train: TrainConfig,
    /// Server-side aggregation threads: 1 = sequential (default), 0 =
    /// auto-detect, n > 1 = a shared n-thread pool for the GAR's sharded
    /// passes. Aggregation results are bit-identical for every setting
    /// (see `runtime::pool`), so this is purely a latency knob.
    pub threads: usize,
    /// Worker transport backend: `pooled` (default) multiplexes the
    /// logical workers over the same shared thread pool — the scaling
    /// path for 100+ workers; `threaded` spawns one OS thread per worker
    /// (the faithful-asynchrony simulation). Seeded runs produce
    /// identical results on either backend, with one caveat: combining
    /// the straggler cost model and first-m abandonment with a nonzero
    /// `drop_prob`/`net_delay_us` makes the fault-RNG streams diverge
    /// between backends (see `transport::ComputeCost`).
    pub transport: TransportKind,
    /// Collection semantics (`collect` root key / `--collect` flag):
    /// `all` (default) waits for every honest worker up to the round
    /// timeout; `first-m` proceeds at the fastest `m = n − f` gradients
    /// — the paper's synchronous model, the knob that exhibits the m/n
    /// slowdown. Stragglers fall through the last-good cache.
    pub collect: CollectMode,
    /// Combine/collection overlap (`overlap` root key / `--overlap`
    /// flag): `off` (default) serialises collect → select → combine;
    /// `prefix` starts selection at the collection quorum and interleaves
    /// the combine+update chunks with the remaining drive slices on the
    /// pooled transport, salvaging late gradients into the straggler
    /// cache. Each round's selection and parameters are bit-identical
    /// either way (the round matrix is frozen at the quorum; combine is
    /// partition-invariant) — but a straggler that *finishes inside the
    /// overlap window* refreshes the last-good cache, so later rounds
    /// that fall back to it use a stale gradient where `off` would have
    /// used an older entry or a zero row. Runs only diverge when such a
    /// salvage occurs; see `coordinator::OverlapMode`.
    pub overlap: OverlapMode,
    /// Prefix-overlap pacing (`overlap_window` root key /
    /// `--overlap-window` flag, ≥ 1): how many combine grid chunks each
    /// drive slice claims. The default 1 is the original
    /// one-aux-task-per-slice behaviour — the longest late-acceptance
    /// window; larger values drain the combine tail in fewer slices.
    /// Pure pacing: parameters are bit-identical for every value (the
    /// chunk grid never changes). Ignored when `overlap = "off"`.
    pub overlap_window: usize,
    /// Gradient wire codec (`codec` root key / `--codec` flag):
    /// `None`/`"off"`/`"raw"` sends raw f32 frames; `"lossless"` is a
    /// bit-exact compressed encoding; `"fp16"`, `"int8"` and `"topk"`
    /// are lossy (quantization / sparsification with error feedback).
    /// Applied on every transport — in-process backends carry encoded
    /// byte payloads, the socket backend negotiates the codec at Hello
    /// (wire spec §7). See `crate::codec`.
    pub codec: Option<crate::codec::CodecKind>,
    /// Two-level aggregation (`groups` root key / `--groups` flag, ≥ 1):
    /// partition the `n` workers into this many groups, stream-reduce each
    /// group's gradients into one vector per group, and run the GAR over
    /// the `groups` group rows instead of the `n` worker rows — the
    /// hierarchy that scales collection to 10k workers without an n×d
    /// matrix. `1` (default) is the flat single-level path, bit-identical
    /// to omitting the knob. `groups > 1` requires `collect = "all"`,
    /// `overlap = "off"` and no codec, and the GAR must satisfy its
    /// resilience precondition at the group level (see `validate()`).
    /// Equivalent to a leading `group(g)` stage in the `gar` pipeline
    /// spec; if both are given they must agree.
    pub groups: usize,
    /// Where to write metrics CSV (None = stdout summary only).
    pub output_dir: Option<String>,
    /// Durable round-journal path (`journal` root key / `--journal`
    /// flag). When set, every committed round appends one checksummed
    /// record (params checksum + selection + membership view + metrics)
    /// to this file, fsync'd before the round is reported. Re-launching
    /// with the same journal resumes from the last committed round by
    /// verified deterministic replay — bit-identical to an uninterrupted
    /// run. `None` (default) disables durability.
    pub journal: Option<String>,
    /// Fault-injection knob (`crash_after_round` root key /
    /// `--crash-after-round` flag): abort the process immediately after
    /// committing this round to the journal — the hook the
    /// crash-recovery CI leg uses to prove exactly-once round semantics.
    /// Requires `journal`.
    pub crash_after_round: Option<u64>,
}

impl ExperimentConfig {
    /// The paper's Fig. 3 base configuration (n=11, f=2, no attack).
    pub fn fig3_default(gar: GarKind) -> Self {
        Self {
            cluster: ClusterConfig {
                n: 11,
                f: 2,
                actual_byzantine: Some(0),
                ..Default::default()
            },
            gar,
            pre: Vec::new(),
            attack: AttackKind::None,
            model: ModelConfig::Artifact {
                name: "cnn".into(),
                dir: "artifacts".into(),
            },
            train: TrainConfig::default(),
            threads: 1,
            transport: TransportKind::default(),
            collect: CollectMode::default(),
            overlap: OverlapMode::default(),
            overlap_window: 1,
            codec: None,
            groups: 1,
            output_dir: None,
            journal: None,
            crash_after_round: None,
        }
    }

    /// Load from a TOML-subset file.
    pub fn from_path(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow::anyhow!("reading config {:?}: {e}", path.as_ref()))?;
        Self::from_text(&text)
    }

    /// Parse from config text.
    pub fn from_text(text: &str) -> Result<Self> {
        let doc = parser::parse(text)?;
        let cfg = Self::from_document(&doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    fn from_document(doc: &Document) -> Result<Self> {
        let root = doc.get("").cloned().unwrap_or_default();
        let get_str = |sec: &str, key: &str| -> Option<String> {
            doc.get(sec)
                .and_then(|s| s.get(key))
                .and_then(|v| v.as_str().ok().map(str::to_string))
        };

        let gar_spec: GarSpec = root
            .get("gar")
            .map(|v| v.as_str())
            .transpose()?
            .unwrap_or("multi-bulyan")
            .parse()?;
        let attack: AttackKind = root
            .get("attack")
            .map(|v| v.as_str())
            .transpose()?
            .unwrap_or("none")
            .parse()?;

        let cluster_sec = doc
            .get("cluster")
            .ok_or_else(|| anyhow::anyhow!("missing [cluster] section"))?;
        let cluster = ClusterConfig {
            n: cluster_sec
                .get("n")
                .ok_or_else(|| anyhow::anyhow!("missing cluster.n"))?
                .as_usize()?,
            f: cluster_sec
                .get("f")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(0),
            actual_byzantine: cluster_sec
                .get("actual_byzantine")
                .map(|v| v.as_usize())
                .transpose()?,
            net_delay_us: cluster_sec
                .get("net_delay_us")
                .map(|v| v.as_u64())
                .transpose()?
                .unwrap_or(0),
            drop_prob: cluster_sec
                .get("drop_prob")
                .map(|v| v.as_f64())
                .transpose()?
                .unwrap_or(0.0),
            round_timeout_ms: cluster_sec
                .get("round_timeout_ms")
                .map(|v| v.as_u64())
                .transpose()?
                .unwrap_or_else(default_round_timeout_ms),
            compute_cost_us: cluster_sec
                .get("compute_cost_us")
                .map(|v| v.as_u64())
                .transpose()?
                .unwrap_or(0),
            stragglers: cluster_sec
                .get("stragglers")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(0),
            straggler_factor: cluster_sec
                .get("straggler_factor")
                .map(|v| v.as_f64())
                .transpose()?
                .unwrap_or(1.0),
            socket_listen: cluster_sec
                .get("socket_listen")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?,
            socket_chunk: cluster_sec
                .get("socket_chunk")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(crate::transport::socket::DEFAULT_CHUNK),
            churn_leave_round: cluster_sec
                .get("churn_leave_round")
                .map(|v| v.as_u64())
                .transpose()?
                .unwrap_or(0),
            churn_workers: cluster_sec
                .get("churn_workers")
                .map(|v| v.as_usize())
                .transpose()?
                .unwrap_or(0),
            churn_rejoin_round: cluster_sec
                .get("churn_rejoin_round")
                .map(|v| v.as_u64())
                .transpose()?
                .unwrap_or(0),
        };

        let model_kind = get_str("model", "kind").unwrap_or_else(|| "quadratic".into());
        let model = if model_kind == "quadratic" {
            let sec = doc.get("model");
            ModelConfig::Quadratic {
                dim: sec
                    .and_then(|s| s.get("dim"))
                    .map(|v| v.as_usize())
                    .transpose()?
                    .unwrap_or(1000),
                noise: sec
                    .and_then(|s| s.get("noise"))
                    .map(|v| v.as_f32())
                    .transpose()?
                    .unwrap_or(0.1),
            }
        } else {
            ModelConfig::Artifact {
                name: model_kind,
                dir: get_str("model", "dir").unwrap_or_else(|| "artifacts".into()),
            }
        };

        let defaults = TrainConfig::default();
        let tsec = doc.get("train");
        let field_f32 = |key: &str, dflt: f32| -> Result<f32> {
            tsec.and_then(|s| s.get(key))
                .map(|v| v.as_f32())
                .transpose()
                .map(|o| o.unwrap_or(dflt))
        };
        let field_usize = |key: &str, dflt: usize| -> Result<usize> {
            tsec.and_then(|s| s.get(key))
                .map(|v| v.as_usize())
                .transpose()
                .map(|o| o.unwrap_or(dflt))
        };
        let train = TrainConfig {
            learning_rate: field_f32("learning_rate", defaults.learning_rate)?,
            momentum: field_f32("momentum", defaults.momentum)?,
            steps: field_usize("steps", defaults.steps)?,
            batch_size: field_usize("batch_size", defaults.batch_size)?,
            eval_every: field_usize("eval_every", defaults.eval_every)?,
            seed: tsec
                .and_then(|s| s.get("seed"))
                .map(|v| v.as_u64())
                .transpose()?
                .unwrap_or(defaults.seed),
        };

        let threads = root
            .get("threads")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(1);
        let transport: TransportKind = root
            .get("transport")
            .map(|v| v.as_str())
            .transpose()?
            .map(str::parse)
            .transpose()?
            .unwrap_or_default();
        let collect: CollectMode = root
            .get("collect")
            .map(|v| v.as_str())
            .transpose()?
            .map(str::parse)
            .transpose()?
            .unwrap_or_default();
        let overlap: OverlapMode = root
            .get("overlap")
            .map(|v| v.as_str())
            .transpose()?
            .map(str::parse)
            .transpose()?
            .unwrap_or_default();
        let overlap_window = root
            .get("overlap_window")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(1);
        // "off" (and absence) disable the codec; anything else must be a
        // known codec name — CodecKind's FromStr lists the valid ones.
        let codec = match root.get("codec").map(|v| v.as_str()).transpose()? {
            None => None,
            Some("off") => None,
            Some(name) => Some(name.parse::<crate::codec::CodecKind>()?),
        };
        let groups = root
            .get("groups")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(1);
        let crash_after_round = root
            .get("crash_after_round")
            .map(|v| v.as_u64())
            .transpose()?;

        Ok(Self {
            cluster,
            gar: gar_spec.kind,
            pre: gar_spec.stages,
            attack,
            model,
            train,
            threads,
            transport,
            collect,
            overlap,
            overlap_window,
            codec,
            groups,
            output_dir: get_str("", "output_dir"),
            journal: get_str("", "journal"),
            crash_after_round,
        })
    }

    /// The full aggregation spec (stages + terminal rule) — the value the
    /// `gar` config key round-trips through.
    pub fn gar_spec(&self) -> GarSpec {
        GarSpec {
            stages: self.pre.clone(),
            kind: self.gar,
        }
    }

    /// The number of aggregation groups actually in effect: the
    /// `group(g)` pipeline stage when the `gar` spec carries one, else
    /// the root `groups` key (default 1 = flat). `validate()` rejects a
    /// misplaced/duplicated stage and any disagreement between the two
    /// spellings, so after validation this is the single source of truth
    /// the launcher reads.
    pub fn effective_groups(&self) -> usize {
        self.gar_spec()
            .group_stage()
            .ok()
            .flatten()
            .unwrap_or(self.groups)
    }

    /// Number of Byzantine workers actually simulated: explicit
    /// `actual_byzantine`, else `f` when an attack is configured, else 0.
    pub fn byzantine_count(&self) -> usize {
        let default = if self.attack == AttackKind::None {
            0
        } else {
            self.cluster.f
        };
        self.cluster.byzantine_count_or(default)
    }

    /// Enforce every precondition before launching.
    pub fn validate(&self) -> Result<()> {
        let (n, f) = (self.cluster.n, self.cluster.f);
        anyhow::ensure!(n >= 1, "cluster.n must be ≥ 1");
        let min_n = self.gar.min_n(f);
        anyhow::ensure!(
            n >= min_n,
            "GAR {} with f={f} requires n ≥ {min_n}, got n={n}",
            self.gar
        );
        let byz = self.byzantine_count();
        anyhow::ensure!(byz <= n, "actual_byzantine={byz} exceeds cluster size n={n}");
        anyhow::ensure!(
            byz == 0 || self.attack != AttackKind::None,
            "cluster has {byz} Byzantine workers but attack = none; \
             set an attack or actual_byzantine = 0"
        );
        for stage in &self.pre {
            stage.validate()?;
        }
        // Two-level aggregation: a `group(g)` stage must be the leading
        // stage (at most once) and agree with the root `groups` key.
        let spec_groups = self.gar_spec().group_stage()?;
        if let Some(g) = spec_groups {
            anyhow::ensure!(
                self.groups == 1 || self.groups == g,
                "group({g}) pipeline stage disagrees with root key groups = {} — \
                 set one of the two, or make them equal",
                self.groups
            );
        }
        let groups = self.effective_groups();
        anyhow::ensure!(groups >= 1, "groups must be ≥ 1 (1 = flat aggregation)");
        anyhow::ensure!(
            groups <= n,
            "groups={groups} exceeds cluster size n={n} — each group needs ≥ 1 worker"
        );
        if groups > 1 {
            anyhow::ensure!(
                self.collect == CollectMode::All,
                "groups={groups} requires collect = \"all\" — group reduction \
                 consumes every honest gradient; first-m abandonment would \
                 leave partial group sums (got collect = {})",
                self.collect
            );
            anyhow::ensure!(
                self.overlap == OverlapMode::Off,
                "groups={groups} requires overlap = \"off\" — the prefix \
                 overlap freezes an n×d round matrix that grouped streaming \
                 collection never materializes"
            );
            anyhow::ensure!(
                self.codec.is_none(),
                "groups={groups} is incompatible with a gradient codec — \
                 lossy/encoded frames cannot be group-reduced server-side \
                 (set codec = \"off\")"
            );
            // GroupMap enforces the partition shape (every group non-empty,
            // Byzantine groups ≤ honest remainder, …).
            crate::gar::GroupMap::new(n, byz, groups)?;
            let root_f = crate::gar::group::root_f_for(n, f, groups);
            let min_g = self.gar.min_n(root_f);
            anyhow::ensure!(
                groups >= min_g,
                "root GAR {} with f_root={root_f} (scaled from f={f} over \
                 {groups} groups) requires groups ≥ {min_g}, got {groups}",
                self.gar
            );
        }
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.cluster.drop_prob),
            "drop_prob must be in [0,1]"
        );
        anyhow::ensure!(
            self.cluster.round_timeout_ms >= 1,
            "round_timeout_ms must be ≥ 1"
        );
        anyhow::ensure!(
            self.cluster.stragglers <= n,
            "stragglers={} exceeds cluster size n={n}",
            self.cluster.stragglers
        );
        anyhow::ensure!(
            self.cluster.straggler_factor >= 1.0,
            "straggler_factor must be ≥ 1 (a straggler is never faster), got {}",
            self.cluster.straggler_factor
        );
        anyhow::ensure!(
            self.cluster.stragglers == 0 || self.cluster.compute_cost_us > 0,
            "stragglers={} needs compute_cost_us > 0 (the cost model is disabled at 0)",
            self.cluster.stragglers
        );
        // Scripted churn: both halves of the knob must be set, the
        // shrunken fleet must still satisfy the GAR's quorum, and rejoin
        // (if any) must come after the departure.
        let churn_on = self.cluster.churn_leave_round > 0 || self.cluster.churn_workers > 0;
        if churn_on {
            anyhow::ensure!(
                self.cluster.churn_leave_round > 0 && self.cluster.churn_workers > 0,
                "scripted churn needs both churn_leave_round ≥ 1 and churn_workers ≥ 1 \
                 (got leave_round={}, workers={})",
                self.cluster.churn_leave_round,
                self.cluster.churn_workers
            );
            let honest = n - byz;
            anyhow::ensure!(
                self.cluster.churn_workers <= honest,
                "churn_workers={} exceeds the {honest} honest workers",
                self.cluster.churn_workers
            );
            let shrunk = n - self.cluster.churn_workers;
            anyhow::ensure!(
                shrunk >= min_n,
                "churn_workers={} shrinks the cluster to {shrunk} < min_n({f}) = {min_n} \
                 for GAR {} — the view change would break the quorum",
                self.cluster.churn_workers,
                self.gar
            );
            anyhow::ensure!(
                self.cluster.churn_rejoin_round == 0
                    || self.cluster.churn_rejoin_round > self.cluster.churn_leave_round,
                "churn_rejoin_round={} must be 0 (never) or > churn_leave_round={}",
                self.cluster.churn_rejoin_round,
                self.cluster.churn_leave_round
            );
            anyhow::ensure!(
                self.effective_groups() == 1,
                "scripted churn requires flat aggregation (groups = 1): the grouped \
                 path pins a full partition of all n workers"
            );
        }
        anyhow::ensure!(
            self.crash_after_round.is_none() || self.journal.is_some(),
            "crash_after_round needs a journal — the crash-injection hook exists \
             to exercise recovery, which requires `journal` to be set"
        );
        anyhow::ensure!(
            self.threads <= MAX_THREADS,
            "threads must be ≤ {MAX_THREADS} (0 = auto, 1 = sequential), got {}",
            self.threads
        );
        anyhow::ensure!(
            self.cluster.socket_chunk >= 1,
            "socket_chunk must be ≥ 1 f32 coordinate per GradientChunk frame"
        );
        anyhow::ensure!(
            self.cluster.socket_listen.is_none() || self.transport == TransportKind::Socket,
            "cluster.socket_listen is set but transport = {} — external workers \
             need transport = \"socket\"",
            self.transport
        );
        anyhow::ensure!(
            self.overlap_window >= 1,
            "overlap_window must be ≥ 1 combine chunk per drive slice"
        );
        anyhow::ensure!(self.train.batch_size >= 1, "batch_size must be ≥ 1");
        anyhow::ensure!(self.train.steps >= 1, "steps must be ≥ 1");
        anyhow::ensure!(self.train.learning_rate > 0.0, "learning_rate must be > 0");
        anyhow::ensure!(
            (0.0..1.0).contains(&self.train.momentum),
            "momentum must be in [0,1)"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::fig3_default(GarKind::MultiBulyan);
        cfg.model = ModelConfig::Quadratic {
            dim: 100,
            noise: 0.1,
        };
        cfg
    }

    #[test]
    fn fig3_default_validates() {
        base().validate().unwrap();
    }

    #[test]
    fn rejects_undersized_cluster() {
        let mut cfg = base();
        cfg.cluster.n = 10; // multi-bulyan needs 4*2+3 = 11
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn rejects_byzantine_without_attack() {
        let mut cfg = base();
        cfg.cluster.actual_byzantine = Some(2);
        cfg.attack = AttackKind::None;
        assert!(cfg.validate().is_err());
        cfg.attack = AttackKind::SignFlip { scale: 1.0 };
        cfg.validate().unwrap();
    }

    #[test]
    fn parse_minimal_config() {
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-krum"
            [cluster]
            n = 7
            f = 2
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.gar, GarKind::MultiKrum);
        assert_eq!(cfg.train.learning_rate, 0.1);
        match cfg.model {
            ModelConfig::Quadratic { dim, .. } => assert_eq!(dim, 1000),
            _ => panic!("wrong model"),
        }
    }

    #[test]
    fn socket_knobs_parse_and_validate() {
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-krum"
            transport = "socket"
            [cluster]
            n = 7
            f = 2
            socket_listen = "tcp:127.0.0.1:7700"
            socket_chunk = 4096
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.transport, TransportKind::Socket);
        assert_eq!(cfg.cluster.socket_listen.as_deref(), Some("tcp:127.0.0.1:7700"));
        assert_eq!(cfg.cluster.socket_chunk, 4096);
        cfg.validate().unwrap();

        // A zero chunk can't frame a gradient.
        let mut bad = cfg.clone();
        bad.cluster.socket_chunk = 0;
        assert!(bad.validate().is_err());

        // An explicit listen address on an in-process transport is a
        // misconfiguration, not a silent no-op.
        let mut mismatched = cfg.clone();
        mismatched.transport = TransportKind::Pooled;
        assert!(mismatched.validate().is_err());

        // Defaults: no listen address, nonzero chunk.
        let dflt = ClusterConfig::default();
        assert_eq!(dflt.socket_listen, None);
        assert!(dflt.socket_chunk >= 1);
    }

    #[test]
    fn parse_full_config_with_artifact_model() {
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-bulyan"
            attack = "little-is-enough"
            [cluster]
            n = 11
            f = 2
            actual_byzantine = 2
            net_delay_us = 100
            drop_prob = 0.01
            [model]
            kind = "mlp"
            dir = "artifacts"
            [train]
            learning_rate = 0.05
            momentum = 0.8
            steps = 100
            batch_size = 10
            eval_every = 20
            seed = 3
            "#,
        )
        .unwrap();
        assert_eq!(cfg.byzantine_count(), 2);
        assert_eq!(cfg.train.seed, 3);
        match &cfg.model {
            ModelConfig::Artifact { name, dir } => {
                assert_eq!(name, "mlp");
                assert_eq!(dir, "artifacts");
            }
            _ => panic!("wrong model"),
        }
    }

    #[test]
    fn gar_pipeline_spec_parses_into_pre_stages() {
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "rmom(0.9)+multi-bulyan"
            [cluster]
            n = 11
            f = 2
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.gar, GarKind::MultiBulyan);
        assert_eq!(
            cfg.pre,
            vec![crate::gar::StageSpec::ResilientMomentum { beta: 0.9 }]
        );
        assert_eq!(cfg.gar_spec().to_string(), "rmom(0.9)+multi-bulyan");
        // A plain GAR keeps the pipeline empty.
        assert!(base().pre.is_empty());
        // Bad stage parameters are a parse error.
        assert!(ExperimentConfig::from_text(
            r#"
            gar = "rmom(1.5)+multi-bulyan"
            [cluster]
            n = 11
            f = 2
            [model]
            kind = "quadratic"
            "#,
        )
        .is_err());
    }

    #[test]
    fn threads_knob_parses_and_validates() {
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-bulyan"
            threads = 4
            [cluster]
            n = 11
            f = 2
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.threads, 4);
        // Default is sequential.
        assert_eq!(base().threads, 1);
        let mut cfg = base();
        cfg.threads = 0; // auto-detect is legal
        cfg.validate().unwrap();
        cfg.threads = 100_000;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn transport_knob_parses_and_defaults_to_pooled() {
        assert_eq!(base().transport, TransportKind::Pooled);
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-bulyan"
            transport = "threaded"
            [cluster]
            n = 11
            f = 2
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.transport, TransportKind::Threaded);
        assert!(ExperimentConfig::from_text(
            r#"
            transport = "smoke-signal"
            [cluster]
            n = 11
            "#,
        )
        .is_err());
    }

    #[test]
    fn collect_knob_parses_and_defaults_to_all() {
        assert_eq!(base().collect, CollectMode::All);
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-bulyan"
            collect = "first-m"
            [cluster]
            n = 11
            f = 2
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.collect, CollectMode::FirstM);
        assert!(ExperimentConfig::from_text(
            r#"
            collect = "fastest"
            [cluster]
            n = 11
            "#,
        )
        .is_err());
    }

    #[test]
    fn overlap_knob_parses_and_defaults_to_off() {
        assert_eq!(base().overlap, OverlapMode::Off);
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-bulyan"
            collect = "first-m"
            overlap = "prefix"
            [cluster]
            n = 11
            f = 2
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.overlap, OverlapMode::Prefix);
        assert!(ExperimentConfig::from_text(
            r#"
            overlap = "pipelined"
            [cluster]
            n = 11
            "#,
        )
        .is_err());
    }

    #[test]
    fn overlap_window_knob_parses_and_validates() {
        assert_eq!(base().overlap_window, 1);
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-bulyan"
            collect = "first-m"
            overlap = "prefix"
            overlap_window = 8
            [cluster]
            n = 11
            f = 2
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.overlap_window, 8);
        // A zero window would stall the prefix tail forever.
        let mut cfg = base();
        cfg.overlap_window = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn codec_knob_parses_and_rejects_unknown_names() {
        use crate::codec::CodecKind;
        assert_eq!(base().codec, None);
        let parse = |name: &str| {
            ExperimentConfig::from_text(&format!(
                r#"
                gar = "multi-bulyan"
                codec = "{name}"
                [cluster]
                n = 11
                f = 2
                [model]
                kind = "quadratic"
                "#,
            ))
        };
        assert_eq!(parse("off").unwrap().codec, None);
        assert_eq!(parse("raw").unwrap().codec, Some(CodecKind::Raw));
        assert_eq!(parse("lossless").unwrap().codec, Some(CodecKind::Lossless));
        assert_eq!(parse("fp16").unwrap().codec, Some(CodecKind::Fp16));
        assert_eq!(parse("int8").unwrap().codec, Some(CodecKind::Int8));
        assert_eq!(parse("topk").unwrap().codec, Some(CodecKind::TopK));
        // Unknown names fail with the valid spellings in the message.
        let err = parse("gzip").unwrap_err().to_string();
        assert!(err.contains("unknown codec 'gzip'"), "{err}");
        assert!(err.contains("raw|lossless|fp16|int8|topk"), "{err}");
    }

    #[test]
    fn groups_knob_parses_and_gates_validate() {
        // Default is flat single-level aggregation.
        assert_eq!(base().groups, 1);
        assert_eq!(base().effective_groups(), 1);
        base().validate().unwrap();

        let grouped = |extra: &str| {
            ExperimentConfig::from_text(&format!(
                r#"
                gar = "trimmed-mean"
                groups = 4
                {extra}
                [cluster]
                n = 12
                f = 1
                [model]
                kind = "quadratic"
                "#,
            ))
        };
        let cfg = grouped("").unwrap();
        assert_eq!(cfg.groups, 4);
        assert_eq!(cfg.effective_groups(), 4);

        // The pipeline spelling (`group(4)+…`) lands in `pre` and is the
        // same knob.
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "group(4)+trimmed-mean"
            [cluster]
            n = 12
            f = 1
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.groups, 1);
        assert_eq!(cfg.effective_groups(), 4);
        assert_eq!(cfg.gar_spec().to_string(), "group(4)+trimmed-mean");

        // Disagreement between the two spellings is rejected.
        assert!(ExperimentConfig::from_text(
            r#"
            gar = "group(4)+trimmed-mean"
            groups = 8
            [cluster]
            n = 12
            f = 1
            [model]
            kind = "quadratic"
            "#,
        )
        .is_err());

        // groups > 1 gates: collect = all, overlap = off, no codec.
        assert!(grouped("collect = \"first-m\"").is_err());
        assert!(grouped("codec = \"lossless\"").is_err());
        let mut cfg = grouped("").unwrap();
        cfg.overlap = OverlapMode::Prefix;
        assert!(cfg.validate().is_err());

        // More groups than workers is rejected.
        let mut cfg = grouped("").unwrap();
        cfg.groups = 13;
        assert!(cfg.validate().is_err());
        // The root GAR quorum scales too: multi-bulyan over 4 groups with
        // f_root = 1 needs ≥ 7 groups.
        let mut cfg = grouped("").unwrap();
        cfg.gar = GarKind::MultiBulyan;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn straggler_cost_model_parses_and_validates() {
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-krum"
            collect = "first-m"
            [cluster]
            n = 7
            f = 2
            compute_cost_us = 500
            stragglers = 2
            straggler_factor = 10.0
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.compute_cost_us, 500);
        assert_eq!(cfg.cluster.stragglers, 2);
        assert_eq!(cfg.cluster.straggler_factor, 10.0);
        // Defaults: model disabled.
        assert_eq!(base().cluster.compute_cost_us, 0);
        assert_eq!(base().cluster.stragglers, 0);
        // Stragglers without a cost base are meaningless.
        let mut cfg = base();
        cfg.cluster.stragglers = 1;
        assert!(cfg.validate().is_err());
        cfg.cluster.compute_cost_us = 100;
        cfg.validate().unwrap();
        // A "straggler" that is faster than baseline is rejected.
        cfg.cluster.straggler_factor = 0.5;
        assert!(cfg.validate().is_err());
        // More stragglers than workers is rejected.
        let mut cfg = base();
        cfg.cluster.compute_cost_us = 100;
        cfg.cluster.stragglers = 100;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn churn_and_journal_knobs_parse_and_validate() {
        let cfg = ExperimentConfig::from_text(
            r#"
            gar = "multi-krum"
            journal = "run.mbj"
            crash_after_round = 4
            [cluster]
            n = 9
            f = 1
            churn_leave_round = 3
            churn_workers = 2
            churn_rejoin_round = 6
            [model]
            kind = "quadratic"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.churn_leave_round, 3);
        assert_eq!(cfg.cluster.churn_workers, 2);
        assert_eq!(cfg.cluster.churn_rejoin_round, 6);
        assert_eq!(cfg.journal.as_deref(), Some("run.mbj"));
        assert_eq!(cfg.crash_after_round, Some(4));

        // Defaults: churn off, no journal.
        assert_eq!(base().cluster.churn_leave_round, 0);
        assert_eq!(base().cluster.churn_workers, 0);
        assert_eq!(base().journal, None);
        assert_eq!(base().crash_after_round, None);

        // Half-set churn is a misconfiguration, not a silent no-op.
        let mut half = cfg.clone();
        half.cluster.churn_workers = 0;
        assert!(half.validate().is_err());

        // The shrunken fleet must still satisfy the quorum: multi-krum
        // with f=1 needs n ≥ 5, so losing 5 of 9 is rejected.
        let mut deep = cfg.clone();
        deep.cluster.churn_workers = 5;
        assert!(deep.validate().is_err());

        // Rejoin, when scheduled, must come after the departure.
        let mut bad_rejoin = cfg.clone();
        bad_rejoin.cluster.churn_rejoin_round = 3;
        assert!(bad_rejoin.validate().is_err());
        bad_rejoin.cluster.churn_rejoin_round = 0; // never — fine
        bad_rejoin.validate().unwrap();

        // Churn is a flat-path knob: the grouped partition is static.
        let mut grouped = cfg.clone();
        grouped.gar = GarKind::TrimmedMean;
        grouped.groups = 3;
        assert!(grouped.validate().is_err());

        // Crash injection without a journal has nothing to recover.
        let mut crash_only = base();
        crash_only.crash_after_round = Some(2);
        assert!(crash_only.validate().is_err());
        crash_only.journal = Some("run.mbj".into());
        crash_only.validate().unwrap();
    }

    #[test]
    fn missing_cluster_section_is_an_error() {
        assert!(ExperimentConfig::from_text("gar = \"average\"").is_err());
    }

    #[test]
    fn bad_hyperparams_rejected() {
        let mut cfg = base();
        cfg.train.momentum = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = base();
        cfg.train.learning_rate = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = base();
        cfg.cluster.drop_prob = 2.0;
        assert!(cfg.validate().is_err());
    }
}
