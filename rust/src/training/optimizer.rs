//! SGD with momentum — the paper's Fig. 3 protocol ("fixed learning rate
//! of 0.1 and momentum 0.9"), in the PyTorch convention the paper's
//! implementation used:
//!
//! ```text
//! v ← µ·v + g
//! x ← x − γ·v
//! ```
//!
//! A fused Pallas kernel with identical semantics ships as the `sgd`
//! artifact (`python/compile/kernels/sgd.py`); an integration test checks
//! native-vs-artifact parity bit-for-bit on random inputs.

use crate::Result;

/// SGD + momentum state.
#[derive(Debug, Clone)]
pub struct Sgd {
    lr: f32,
    momentum: f32,
    velocity: Vec<f32>,
}

impl Sgd {
    pub fn new(dim: usize, lr: f32, momentum: f32) -> Result<Self> {
        anyhow::ensure!(lr > 0.0, "sgd: lr must be > 0");
        anyhow::ensure!((0.0..1.0).contains(&momentum), "sgd: momentum in [0,1)");
        Ok(Self {
            lr,
            momentum,
            velocity: vec![0.0; dim],
        })
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Override the learning rate (schedules).
    pub fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    pub fn momentum(&self) -> f32 {
        self.momentum
    }

    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Mutable velocity buffer — the fused combine+update pass
    /// (`coordinator::core::fused_combine_update`) shards it alongside
    /// the parameter vector; per-coordinate arithmetic is exactly
    /// [`step`](Self::step)'s, so the fused pass is bit-identical.
    pub fn velocity_mut(&mut self) -> &mut [f32] {
        &mut self.velocity
    }

    /// One update step in place.
    pub fn step(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), self.velocity.len(), "sgd: dim mismatch");
        assert_eq!(grad.len(), params.len(), "sgd: grad dim mismatch");
        let (mu, lr) = (self.momentum, self.lr);
        for i in 0..params.len() {
            self.velocity[i] = mu * self.velocity[i] + grad[i];
            params[i] -= lr * self.velocity[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_without_momentum() {
        let mut opt = Sgd::new(2, 0.5, 0.0).unwrap();
        let mut p = vec![1.0f32, 2.0];
        opt.step(&mut p, &[2.0, -2.0]);
        assert_eq!(p, vec![0.0, 3.0]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(1, 1.0, 0.5).unwrap();
        let mut p = vec![0.0f32];
        opt.step(&mut p, &[1.0]); // v=1, p=-1
        assert_eq!(p, vec![-1.0]);
        opt.step(&mut p, &[1.0]); // v=1.5, p=-2.5
        assert_eq!(p, vec![-2.5]);
        assert_eq!(opt.velocity(), &[1.5]);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize ‖x − c‖²/2, gradient x − c.
        let c = [3.0f32, -4.0];
        let mut p = vec![0.0f32, 0.0];
        let mut opt = Sgd::new(2, 0.1, 0.9).unwrap();
        for _ in 0..300 {
            let g: Vec<f32> = p.iter().zip(&c).map(|(x, t)| x - t).collect();
            opt.step(&mut p, &g);
        }
        assert!((p[0] - 3.0).abs() < 1e-3 && (p[1] + 4.0).abs() < 1e-3);
    }

    #[test]
    fn invalid_hyperparams() {
        assert!(Sgd::new(1, 0.0, 0.5).is_err());
        assert!(Sgd::new(1, 0.1, 1.0).is_err());
    }
}
