//! Learning-rate schedules. The paper uses a fixed rate (Fig. 3); the
//! other schedules support the convergence requirements of Lemma 2
//! (`Σγ_t = ∞`, `Σγ_t² < ∞` — satisfied by `InvSqrt`/`Inv`) and the
//! warmup ablations.

/// γ_t as a function of the step index t (0-based).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    /// γ_t = base (the paper's Fig. 3 protocol).
    Fixed { base: f32 },
    /// γ_t = base / (1 + t/decay) — satisfies Lemma 2's conditions.
    Inv { base: f32, decay: f32 },
    /// γ_t = base / √(1 + t/decay).
    InvSqrt { base: f32, decay: f32 },
    /// Linear warmup over `warmup` steps, then fixed.
    Warmup { base: f32, warmup: usize },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            LrSchedule::Fixed { base } => base,
            LrSchedule::Inv { base, decay } => base / (1.0 + step as f32 / decay),
            LrSchedule::InvSqrt { base, decay } => base / (1.0 + step as f32 / decay).sqrt(),
            LrSchedule::Warmup { base, warmup } => {
                if warmup == 0 || step >= warmup {
                    base
                } else {
                    base * (step + 1) as f32 / warmup as f32
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_constant() {
        let s = LrSchedule::Fixed { base: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(10_000), 0.1);
    }

    #[test]
    fn inv_decays_harmonically() {
        let s = LrSchedule::Inv {
            base: 1.0,
            decay: 10.0,
        };
        assert_eq!(s.at(0), 1.0);
        assert!((s.at(10) - 0.5).abs() < 1e-6);
        // Σγ² < ∞ requires γ_t → 0 at least as 1/t.
        assert!(s.at(10_000) < 2e-3);
    }

    #[test]
    fn warmup_ramps_then_holds() {
        let s = LrSchedule::Warmup {
            base: 0.2,
            warmup: 4,
        };
        assert!((s.at(0) - 0.05).abs() < 1e-6);
        assert!((s.at(3) - 0.2).abs() < 1e-6);
        assert_eq!(s.at(100), 0.2);
    }

    #[test]
    fn invsqrt_between_fixed_and_inv() {
        let f = LrSchedule::Fixed { base: 1.0 };
        let i = LrSchedule::Inv { base: 1.0, decay: 5.0 };
        let h = LrSchedule::InvSqrt { base: 1.0, decay: 5.0 };
        for t in [1usize, 10, 100] {
            assert!(h.at(t) <= f.at(t));
            assert!(h.at(t) >= i.at(t));
        }
    }
}
