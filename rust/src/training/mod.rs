//! Optimizer and learning-rate schedules — the server-side update rule
//! (Equation 2 of the paper: `x ← x − γ·GAR(G_1..G_n)`).

mod optimizer;
mod schedule;

pub use optimizer::Sgd;
pub use schedule::LrSchedule;
