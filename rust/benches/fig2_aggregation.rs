//! Bench mirroring the paper's Fig. 2: aggregation time of MULTI-KRUM /
//! MULTI-BULYAN / MEDIAN over (n, d) points, using the in-repo
//! `TimingProtocol` harness (criterion is unavailable offline; the
//! protocol is the paper's own — 7 runs, keep the 5 closest to the
//! median, report mean ± std).
//!
//! Run with `cargo bench --bench fig2_aggregation`. The CLI harness
//! (`multibulyan bench fig2 [--full]`) runs the full grid and writes CSV.

use multibulyan::bench::fig2_f;
use multibulyan::gar::{GarKind, GarScratch};
use multibulyan::metrics::TimingProtocol;
use multibulyan::tensor::GradMatrix;
use multibulyan::util::Rng64;

fn main() {
    let fast = std::env::var("MB_BENCH_FAST").is_ok();
    let dims: &[usize] = if fast {
        &[10_000]
    } else {
        &[100_000, 1_000_000]
    };
    let ns: &[usize] = if fast { &[7, 15] } else { &[7, 15, 23] };
    let protocol = TimingProtocol::default();
    println!("fig2_aggregation — {protocol:?}");
    println!(
        "{:<14} {:>4} {:>4} {:>10} {:>12} {:>10} {:>14}",
        "gar", "n", "f", "d", "mean_ms", "std_ms", "GB/s(read)"
    );
    for &d in dims {
        for &n in ns {
            let f = fig2_f(n);
            let mut rng = Rng64::seed_from_u64(1);
            let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
            for kind in [GarKind::MultiKrum, GarKind::MultiBulyan, GarKind::Median] {
                if n < kind.min_n(f) {
                    continue;
                }
                let gar = kind.instantiate(n, f).unwrap();
                let mut out = vec![0.0f32; d];
                let mut scratch = GarScratch::new();
                let (mean_ms, std_ms) = protocol.measure(|| {
                    gar.aggregate_with_scratch(&grads, &mut out, &mut scratch)
                        .unwrap()
                });
                // Effective read bandwidth over the n·d input matrix.
                let gbs = (n * d * 4) as f64 / (mean_ms / 1e3) / 1e9;
                println!(
                    "{:<14} {:>4} {:>4} {:>10} {:>12.3} {:>10.3} {:>14.2}",
                    kind.as_str(),
                    n,
                    f,
                    d,
                    mean_ms,
                    std_ms,
                    gbs
                );
            }
        }
    }
}
