//! End-to-end round latency: broadcast → collect → forge → aggregate →
//! update on the rust-native workload. This is the L3 latency budget the
//! perf pass tracks — the coordinator overhead must stay negligible next
//! to the gradient computation + aggregation itself.

use multibulyan::attacks::AttackKind;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::launch;
use multibulyan::gar::GarKind;
use multibulyan::metrics::TimingProtocol;

fn main() {
    let protocol = TimingProtocol::default();
    println!("coordinator_round — {protocol:?}");
    println!(
        "{:<14} {:>10} {:>12} {:>10} {:>14}",
        "gar", "d", "mean_ms", "std_ms", "agg_share"
    );
    for (gar, dim) in [
        (GarKind::Average, 100_000usize),
        (GarKind::MultiKrum, 100_000),
        (GarKind::MultiBulyan, 100_000),
    ] {
        let config = ExperimentConfig {
            cluster: ClusterConfig {
                n: 11,
                f: if gar == GarKind::Average { 0 } else { 2 },
                actual_byzantine: Some(if gar == GarKind::Average { 0 } else { 2 }),
                net_delay_us: 0,
                drop_prob: 0.0,
                round_timeout_ms: 60_000,
                ..Default::default()
            },
            gar,
            pre: Vec::new(),
            attack: if gar == GarKind::Average {
                AttackKind::None
            } else {
                AttackKind::LittleIsEnough { z: None }
            },
            model: ModelConfig::Quadratic { dim, noise: 0.1 },
            train: TrainConfig {
                learning_rate: 0.01,
                momentum: 0.9,
                steps: 1,
                batch_size: 8,
                eval_every: 0,
                seed: 1,
            },
            threads: 1,
            transport: Default::default(),
            collect: Default::default(),
            overlap: Default::default(),
            overlap_window: 1,
            codec: None,
            groups: 1,
            output_dir: None,
            journal: None,
            crash_after_round: None,
        };
        let mut cluster = launch(&config, None).unwrap();
        let (mean_ms, std_ms) = protocol.measure(|| {
            let view = cluster.coordinator.next_view();
            cluster.coordinator.run_round(&view).unwrap();
        });
        // Fraction of the round spent inside the GAR itself.
        let agg_ms = cluster
            .coordinator
            .metrics
            .timer("aggregate")
            .map(|t| t.mean() * 1e3)
            .unwrap_or(0.0);
        println!(
            "{:<14} {:>10} {:>12.3} {:>10.3} {:>13.1}%",
            gar.as_str(),
            dim,
            mean_ms,
            std_ms,
            100.0 * agg_ms / mean_ms.max(1e-9)
        );
        cluster.coordinator.shutdown();
    }
}
