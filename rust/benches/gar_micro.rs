//! Micro-benchmarks of the GAR building blocks: the pairwise-distance
//! kernel (the O(n²d) hot spot), Krum scoring from cached distances, and
//! the per-coordinate median pass — the three loops the perf pass
//! optimises (EXPERIMENTS.md §Perf).

use multibulyan::gar::{
    krum_scores_from_distances, pairwise_sq_distances_into, GarKind, GarScratch,
};
use multibulyan::metrics::TimingProtocol;
use multibulyan::tensor::GradMatrix;
use multibulyan::util::Rng64;

fn main() {
    let protocol = TimingProtocol::default();
    println!("gar_micro — {protocol:?}\n");

    println!("pairwise squared distances (the O(n²d) hot spot):");
    for (n, d) in [(11usize, 100_000usize), (25, 100_000), (11, 1_000_000)] {
        let mut rng = Rng64::seed_from_u64(7);
        let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
        let mut out = vec![0.0f32; n * n];
        let (mean_ms, std_ms) = protocol.measure(|| pairwise_sq_distances_into(&grads, &mut out));
        let gbs = (n * d * 4) as f64 / (mean_ms / 1e3) / 1e9;
        println!(
            "  n={n:<3} d={d:<9} {mean_ms:>10.3} ± {std_ms:<8.3} ms   {gbs:>6.2} GB/s(read)"
        );
    }

    println!("\nkrum scoring from cached distances (O(n²), must be negligible):");
    {
        let n = 39;
        let dist: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32).collect();
        let pool: Vec<usize> = (0..n).collect();
        let mut scores = Vec::new();
        let (mean_ms, std_ms) =
            protocol.measure(|| krum_scores_from_distances(&dist, n, &pool, 9, &mut scores));
        println!("  n=39            {mean_ms:>10.4} ± {std_ms:<8.4} ms");
    }

    println!("\ncoordinate-wise median (O(nd) column pass):");
    for d in [100_000usize, 1_000_000] {
        let n = 11;
        let mut rng = Rng64::seed_from_u64(3);
        let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
        let gar = GarKind::Median.instantiate(n, 2).unwrap();
        let mut out = vec![0.0f32; d];
        let mut scratch = GarScratch::new();
        let (mean_ms, std_ms) = protocol.measure(|| {
            gar.aggregate_with_scratch(&grads, &mut out, &mut scratch)
                .unwrap()
        });
        let gbs = (n * d * 4) as f64 / (mean_ms / 1e3) / 1e9;
        println!(
            "  n={n:<3} d={d:<9} {mean_ms:>10.3} ± {std_ms:<8.3} ms   {gbs:>6.2} GB/s(read)"
        );
    }
}
