//! Micro-benchmarks of the GAR building blocks: the pairwise-distance
//! kernel (the O(n²d) hot spot), Krum scoring from cached distances, the
//! per-coordinate median pass — the three loops the perf pass optimises
//! (EXPERIMENTS.md §Perf) — plus the thread-scaling sweep of the sharded
//! parallel engine (`MB_THREADS=1,2,4` to override the sweep). The
//! full-GAR thread sweep is `bench::slowdown::thread_sweep` (the same
//! harness the `bench threads` CLI and the CI perf gate run); this bench
//! invokes it with the CSV side effect disabled.

use multibulyan::gar::{
    krum_scores_from_distances, pairwise_sq_distances_into, pairwise_sq_distances_sharded,
    GarKind, GarScratch,
};
use multibulyan::metrics::TimingProtocol;
use multibulyan::runtime::Parallelism;
use multibulyan::tensor::GradMatrix;
use multibulyan::util::Rng64;

/// Thread counts to sweep: `MB_THREADS=1,2,4,8` overrides; default 1,2,4.
fn sweep_thread_counts() -> Vec<usize> {
    std::env::var("MB_THREADS")
        .ok()
        .map(|s| {
            s.split(',')
                .filter_map(|t| t.trim().parse().ok())
                .filter(|&t| t >= 1)
                .collect()
        })
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| vec![1, 2, 4])
}

fn main() {
    let protocol = TimingProtocol::default();
    println!("gar_micro — {protocol:?}\n");

    println!("pairwise squared distances (the O(n²d) hot spot):");
    for (n, d) in [(11usize, 100_000usize), (25, 100_000), (11, 1_000_000)] {
        let mut rng = Rng64::seed_from_u64(7);
        let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
        let mut out = vec![0.0f32; n * n];
        let (mean_ms, std_ms) = protocol.measure(|| pairwise_sq_distances_into(&grads, &mut out));
        let gbs = (n * d * 4) as f64 / (mean_ms / 1e3) / 1e9;
        println!(
            "  n={n:<3} d={d:<9} {mean_ms:>10.3} ± {std_ms:<8.3} ms   {gbs:>6.2} GB/s(read)"
        );
    }

    println!("\nkrum scoring from cached distances (O(n²), must be negligible):");
    {
        let n = 39;
        let dist: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32).collect();
        let pool: Vec<usize> = (0..n).collect();
        let mut scores = Vec::new();
        let (mean_ms, std_ms) =
            protocol.measure(|| krum_scores_from_distances(&dist, n, &pool, 9, &mut scores));
        println!("  n=39            {mean_ms:>10.4} ± {std_ms:<8.4} ms");
    }

    println!("\ncoordinate-wise median (O(nd) column pass):");
    for d in [100_000usize, 1_000_000] {
        let n = 11;
        let mut rng = Rng64::seed_from_u64(3);
        let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
        let gar = GarKind::Median.instantiate(n, 2).unwrap();
        let mut out = vec![0.0f32; d];
        let mut scratch = GarScratch::new();
        let (mean_ms, std_ms) = protocol.measure(|| {
            gar.aggregate_with_scratch(&grads, &mut out, &mut scratch)
                .unwrap()
        });
        let gbs = (n * d * 4) as f64 / (mean_ms / 1e3) / 1e9;
        println!(
            "  n={n:<3} d={d:<9} {mean_ms:>10.3} ± {std_ms:<8.3} ms   {gbs:>6.2} GB/s(read)"
        );
    }

    // -- thread-scaling sweep of the sharded parallel engine -------------
    let thread_counts = sweep_thread_counts();

    println!("\nsharded pairwise distances, thread sweep (n=11):");
    for d in [100_000usize, 1_000_000] {
        let n = 11;
        let mut rng = Rng64::seed_from_u64(17);
        let grads = GradMatrix::uniform(n, d, 0.0, 1.0, &mut rng);
        let mut out = vec![0.0f32; n * n];
        let mut base: Option<(f64, Vec<f32>)> = None;
        for &threads in &thread_counts {
            let par = Parallelism::new(threads);
            let mut partials = Vec::new();
            let (mean_ms, _) = protocol.measure(|| {
                pairwise_sq_distances_sharded(&grads, &mut out, &par, &mut partials)
            });
            match &base {
                None => base = Some((mean_ms, out.clone())),
                Some((base_ms, reference)) => {
                    assert_eq!(reference, &out, "thread count changed the distances");
                    println!(
                        "  d={d:<9} threads={threads:<3} {mean_ms:>10.3} ms   speedup ×{:.2}",
                        base_ms / mean_ms.max(1e-9)
                    );
                    continue;
                }
            }
            println!("  d={d:<9} threads={threads:<3} {mean_ms:>10.3} ms   speedup ×1.00");
        }
    }

    println!("\nfull GAR aggregation, thread sweep (n=11, f=2):");
    // One harness, three consumers: this bench, the `bench threads` CLI
    // and the CI perf gate all run `slowdown::thread_sweep` (which also
    // asserts thread counts don't change the aggregate). CSV disabled —
    // writing results/ is the CLI's job, not a micro-bench's.
    multibulyan::bench::slowdown::thread_sweep(
        11,
        2,
        &[100_000, 1_000_000],
        &thread_counts,
        &[GarKind::MultiKrum, GarKind::MultiBulyan, GarKind::Median],
        protocol,
        false,
        false,
    )
    .expect("full-GAR thread sweep failed");
}
