//! Fixture tests for the in-repo invariant linter: one positive (fires)
//! and one negative (stays quiet) case per rule, the escape grammar, the
//! directory walk, and the self-check that the real repo lints clean.
//!
//! Fixtures are assembled from string literals — the scanner blanks
//! string contents, so this file can quote forbidden tokens freely; its
//! own comments, however, must not spell out a malformed allow escape.

use multibulyan::lint::{lint_repo, lint_source, rules, Finding, LINT_DIRS};
use std::path::Path;

/// Findings for `src` linted as if it were the library file `rel`.
fn lint_at(rel: &str, src: &str) -> Vec<Finding> {
    lint_source(rel, src)
}

fn rules_hit(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ---------------------------------------------------------------- unsafe

#[test]
fn unsafe_outside_audited_modules_fires() {
    let src = "fn f(p: *mut f32) {\n    unsafe { *p = 1.0; }\n}\n";
    let findings = lint_at("rust/src/gar/krum.rs", src);
    assert_eq!(rules_hit(&findings), vec!["unsafe-block"]);
    assert_eq!(findings[0].line, 2);
}

#[test]
fn unsafe_in_audited_module_without_safety_comment_fires() {
    let src = "fn f(p: *mut f32) {\n    unsafe { *p = 1.0; }\n}\n";
    let findings = lint_at("rust/src/runtime/pool.rs", src);
    assert_eq!(rules_hit(&findings), vec!["unsafe-block"]);
}

#[test]
fn unsafe_with_safety_comment_in_audited_module_is_quiet() {
    let src = "fn f(p: *mut f32) {\n    // SAFETY: caller guarantees exclusivity.\n    unsafe { *p = 1.0; }\n}\n";
    assert!(lint_at("rust/src/runtime/pool.rs", src).is_empty());
}

#[test]
fn unsafe_in_string_literal_is_quiet() {
    let src = "fn f() -> &'static str {\n    \"unsafe is just a word here\"\n}\n";
    assert!(lint_at("rust/src/gar/krum.rs", src).is_empty());
}

// ------------------------------------------------------------ wall-clock

#[test]
fn instant_without_annotation_fires() {
    let src = "use std::time::Instant;\nfn f() {\n    let t = Instant::now();\n    drop(t);\n}\n";
    let findings = lint_at("rust/src/gar/krum.rs", src);
    assert_eq!(rules_hit(&findings), vec!["wall-clock", "wall-clock"]);
}

#[test]
fn instant_with_wall_clock_annotation_is_quiet() {
    let src = "// wall-clock: measures the benchmark itself.\nuse std::time::Instant;\nfn f() {\n    // wall-clock: ditto.\n    let t = Instant::now();\n    drop(t);\n}\n";
    assert!(lint_at("rust/src/gar/krum.rs", src).is_empty());
}

#[test]
fn instant_inside_cfg_test_is_quiet() {
    let src = "#[cfg(test)]\nmod tests {\n    use std::time::Instant;\n}\n";
    assert!(lint_at("rust/src/gar/krum.rs", src).is_empty());
}

#[test]
fn instantiate_identifier_does_not_trip_word_boundary() {
    let src = "fn instantiate() {}\nstruct Instantiator;\n";
    assert!(lint_at("rust/src/gar/krum.rs", src).is_empty());
}

// ---------------------------------------------------------- thread-spawn

#[test]
fn thread_spawn_outside_runtime_fires() {
    let src = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
    let findings = lint_at("rust/src/gar/krum.rs", src);
    assert_eq!(rules_hit(&findings), vec!["thread-spawn"]);
}

#[test]
fn thread_builder_outside_runtime_fires() {
    let src = "fn f() {\n    let b = std::thread::Builder::new();\n    drop(b);\n}\n";
    let findings = lint_at("examples/quickstart.rs", src);
    assert_eq!(rules_hit(&findings), vec!["thread-spawn"]);
}

#[test]
fn thread_spawn_under_runtime_and_transport_is_quiet() {
    let src = "fn f() {\n    std::thread::spawn(|| {});\n}\n";
    assert!(lint_at("rust/src/runtime/pool.rs", src).is_empty());
    assert!(lint_at("rust/src/transport/threaded.rs", src).is_empty());
}

// ------------------------------------------------------------- hash-iter

#[test]
fn hashmap_without_annotation_fires() {
    let src = "use std::collections::HashMap;\nfn f() {\n    let m: HashMap<u32, u32> = HashMap::new();\n    drop(m);\n}\n";
    let findings = lint_at("rust/src/metrics/recorder.rs", src);
    assert!(rules_hit(&findings).iter().all(|&r| r == "hash-iter"));
    assert!(!findings.is_empty());
}

#[test]
fn hashmap_with_sorted_annotation_is_quiet() {
    let src = "// LINT: sorted -- keyed access only; never iterated.\nuse std::collections::HashMap;\nfn f() {\n    // LINT: sorted -- ditto.\n    let m: HashMap<u32, u32> = HashMap::new();\n    drop(m);\n}\n";
    assert!(lint_at("rust/src/metrics/recorder.rs", src).is_empty());
}

#[test]
fn btreemap_is_quiet() {
    let src = "use std::collections::BTreeMap;\nfn f() {\n    let m: BTreeMap<u32, u32> = BTreeMap::new();\n    drop(m);\n}\n";
    assert!(lint_at("rust/src/metrics/recorder.rs", src).is_empty());
}

// ----------------------------------------------------------- float-reduce

#[test]
fn bare_float_sum_in_scope_fires() {
    let src = "fn f(xs: &[f32]) -> f32 {\n    xs.iter().sum::<f32>()\n}\n";
    let findings = lint_at("rust/src/gar/krum.rs", src);
    assert_eq!(rules_hit(&findings), vec!["float-reduce"]);
}

#[test]
fn bare_fold_in_scope_fires() {
    let src = "fn f(xs: &[f32]) -> f32 {\n    xs.iter().fold(0.0, |a, b| a + b)\n}\n";
    let findings = lint_at("rust/src/tensor/ops.rs", src);
    assert_eq!(rules_hit(&findings), vec!["float-reduce"]);
}

#[test]
fn annotated_or_exempt_float_reduction_is_quiet() {
    let annotated = "fn f(xs: &[f32]) -> f32 {\n    // LINT: reduce-ok -- n-length column, sequential index order.\n    xs.iter().sum::<f32>()\n}\n";
    assert!(lint_at("rust/src/gar/krum.rs", annotated).is_empty());
    // The designated reducers are exempt wholesale.
    let bare = "fn f(xs: &[f32]) -> f32 {\n    xs.iter().sum::<f32>()\n}\n";
    assert!(lint_at("rust/src/gar/pairwise.rs", bare).is_empty());
    assert!(lint_at("rust/src/tensor/stats.rs", bare).is_empty());
}

#[test]
fn integer_sum_is_quiet() {
    let src = "fn f(xs: &[usize]) -> usize {\n    xs.iter().sum::<usize>()\n}\n";
    assert!(lint_at("rust/src/gar/krum.rs", src).is_empty());
    let src64 = "fn f(xs: &[u64]) -> u64 {\n    xs.iter().sum::<u64>()\n}\n";
    assert!(lint_at("rust/src/coordinator/core.rs", src64).is_empty());
}

#[test]
fn out_of_scope_dirs_are_not_checked_for_reductions() {
    let src = "fn f(xs: &[f32]) -> f32 {\n    xs.iter().sum::<f32>()\n}\n";
    assert!(lint_at("rust/src/util/rng.rs", src).is_empty());
}

// ---------------------------------------------------------- allow-syntax

#[test]
fn well_formed_allow_escape_suppresses_the_finding() {
    let src = "fn f(xs: &[f32]) -> f32 {\n    // lint:allow(float-reduce) -- scalar diagnostic, not a gradient.\n    xs.iter().sum::<f32>()\n}\n";
    assert!(lint_at("rust/src/gar/krum.rs", src).is_empty());
}

#[test]
fn allow_escape_without_reason_fires_and_suppresses_nothing() {
    let src = "fn f(xs: &[f32]) -> f32 {\n    // lint:allow(float-reduce)\n    xs.iter().sum::<f32>()\n}\n";
    let findings = lint_at("rust/src/gar/krum.rs", src);
    let mut hit = rules_hit(&findings);
    hit.sort_unstable();
    assert_eq!(hit, vec!["allow-syntax", "float-reduce"]);
}

#[test]
fn allow_escape_with_unknown_rule_fires() {
    let src = "fn f() {\n    // lint:allow(no-such-rule) -- misremembered the id.\n    let x = 1;\n    drop(x);\n}\n";
    let findings = lint_at("rust/src/gar/krum.rs", src);
    assert_eq!(rules_hit(&findings), vec!["allow-syntax"]);
    assert!(findings[0].message.contains("no-such-rule"));
}

#[test]
fn allow_escape_for_a_different_rule_does_not_suppress() {
    let src = "fn f(xs: &[f32]) -> f32 {\n    // lint:allow(wall-clock) -- names the wrong rule.\n    xs.iter().sum::<f32>()\n}\n";
    let findings = lint_at("rust/src/gar/krum.rs", src);
    assert_eq!(rules_hit(&findings), vec!["float-reduce"]);
}

// ------------------------------------------------------- walk + self-check

#[test]
fn lint_repo_walks_a_tree_and_reports_file_line_rule() {
    let dir = std::env::temp_dir().join(format!("mb-lint-fixture-{}", std::process::id()));
    let src_dir = dir.join("rust/src/gar");
    std::fs::create_dir_all(&src_dir).unwrap();
    std::fs::write(
        src_dir.join("bad.rs"),
        "fn f(p: *mut f32) {\n    unsafe { *p = 1.0; }\n}\n",
    )
    .unwrap();
    std::fs::write(src_dir.join("good.rs"), "pub fn g() {}\n").unwrap();
    let report = lint_repo(&dir).unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
    assert_eq!(report.files_scanned, 2);
    assert_eq!(report.findings.len(), 1);
    let f = &report.findings[0];
    assert_eq!(f.file, "rust/src/gar/bad.rs");
    assert_eq!(f.line, 2);
    assert_eq!(f.rule, "unsafe-block");
}

#[test]
fn rule_catalog_is_complete() {
    assert_eq!(rules::RULES.len(), 6);
    for rule in rules::RULES {
        assert!(!rule.summary.is_empty(), "{} has no summary", rule.id);
        assert!(!rule.escape.is_empty(), "{} has no escape doc", rule.id);
    }
}

/// The acceptance-criterion self-check: the real repo lints clean.
#[test]
fn repo_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let report = lint_repo(&root).unwrap();
    assert!(
        report.findings.is_empty(),
        "lint findings on the seed tree:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    assert!(
        report.files_scanned >= 70,
        "walk looks truncated: only {} files under {:?}",
        report.files_scanned,
        LINT_DIRS
    );
}

/// Acceptance criterion: the four unsafe-bearing modules pass with real
/// SAFETY arguments, not allow escapes.
#[test]
fn unsafe_modules_carry_no_allow_escapes() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    for rel in rules::UNSAFE_MODULES {
        let text = std::fs::read_to_string(root.join(rel)).unwrap();
        assert!(
            !text.contains("lint:allow"),
            "{rel} uses an allow escape instead of a SAFETY argument"
        );
    }
}

/// Acceptance criterion: the journal + membership modules sit inside
/// the full catalog's scope (they live under `rust/src/coordinator/`,
/// a float-reduce directory — the durable-recovery path must be as
/// deterministic as the aggregation it replays) and carry zero
/// escapes of any kind.
#[test]
fn journal_and_membership_modules_are_in_scope_with_zero_escapes() {
    for rel in [
        "rust/src/coordinator/journal.rs",
        "rust/src/coordinator/membership.rs",
    ] {
        assert!(
            rules::FLOAT_REDUCE_SCOPE
                .iter()
                .any(|p| rel.starts_with(p)),
            "{rel} fell out of the float-reduce scope"
        );
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let text = std::fs::read_to_string(root.join(rel)).unwrap();
        assert!(
            !text.contains("lint:allow") && !text.contains("LINT:"),
            "{rel} uses a lint escape; the journal/membership layer \
             must pass the catalog clean"
        );
        let findings = lint_source(rel, &text);
        assert!(
            findings.is_empty(),
            "{rel} has lint findings: {findings:?}"
        );
    }
}
