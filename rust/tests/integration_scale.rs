//! Scale tests for the pooled worker runtime: full training round-trips
//! with 128+ logical workers — cluster sizes the thread-per-worker
//! transport would need one OS thread each for — including fault-model
//! drops (exercising the stale-slot discard + last-known-gradient
//! fallback) and a live Byzantine attack.

use multibulyan::attacks::AttackKind;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::launch;
use multibulyan::gar::GarKind;
use multibulyan::transport::TransportKind;

fn pooled_exp(n: usize, f: usize, byz: usize, attack: AttackKind, steps: usize) -> ExperimentConfig {
    ExperimentConfig {
        cluster: ClusterConfig {
            n,
            f,
            actual_byzantine: Some(byz),
            net_delay_us: 0,
            drop_prob: 0.0,
            round_timeout_ms: 60_000,
            ..Default::default()
        },
        gar: GarKind::MultiKrum,
        pre: Vec::new(),
        attack,
        model: ModelConfig::Quadratic {
            dim: 64,
            noise: 0.3,
        },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.0,
            steps,
            batch_size: 8,
            eval_every: 0,
            seed: 9,
        },
        threads: 2,
        transport: TransportKind::Pooled,
        collect: Default::default(),
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    }
}

#[test]
fn pooled_runtime_trains_131_workers_with_drops_and_byzantine_attack() {
    // 131 workers, 8 of them a sign-flip coalition, 5% gradient drops:
    // the pooled runtime must keep every round square (straggler
    // fallback), filter the attack, and converge.
    let mut exp = pooled_exp(131, 8, 8, AttackKind::SignFlip { scale: 5.0 }, 60);
    exp.cluster.drop_prob = 0.05;
    let cluster = launch(&exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    let mut evaluator = cluster.evaluator;
    coordinator.train(60, 0, &mut evaluator).unwrap();
    let loss = coordinator.metrics.final_loss().unwrap();
    let missing = coordinator.metrics.counter("gradients_missing");
    assert!(coordinator.params().iter().all(|v| v.is_finite()));
    coordinator.shutdown();
    // 123 honest workers × 60 rounds × 5% ⇒ hundreds of simulated drops.
    assert!(missing > 0, "drop injection produced no missing gradients");
    assert!(
        loss < 0.01,
        "131-worker pooled run failed to converge: loss {loss}"
    );
}

#[test]
fn pooled_runtime_handles_512_logical_workers_per_round() {
    // 512 logical workers in-process — a pure transport-scaling check:
    // every round must collect all honest gradients with zero drops.
    let mut exp = pooled_exp(512, 40, 0, AttackKind::None, 2);
    exp.model = ModelConfig::Quadratic {
        dim: 32,
        noise: 0.2,
    };
    let cluster = launch(&exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    for _ in 0..2 {
        let view = coordinator.next_view();
        let outcome = coordinator.run_round(&view).unwrap();
        assert_eq!(outcome.collected, 512, "round {}", outcome.round);
        assert_eq!(outcome.missing, 0);
    }
    assert!(coordinator.params().iter().all(|v| v.is_finite()));
    coordinator.shutdown();
}

#[test]
fn pooled_and_threaded_runs_are_bit_identical_at_scale() {
    // A 64-worker seeded run must land on the same parameters on both
    // transports (counter-seeded gradients + per-worker fault RNGs).
    let run = |transport: TransportKind| -> Vec<f32> {
        let mut exp = pooled_exp(64, 4, 4, AttackKind::SignFlip { scale: 2.0 }, 10);
        exp.transport = transport;
        let cluster = launch(&exp, None).unwrap();
        let mut coordinator = cluster.coordinator;
        for _ in 0..10 {
            let view = coordinator.next_view();
            coordinator.run_round(&view).unwrap();
        }
        let params = coordinator.params().to_vec();
        coordinator.shutdown();
        params
    };
    assert_eq!(run(TransportKind::Pooled), run(TransportKind::Threaded));
}
