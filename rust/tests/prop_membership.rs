//! Property tests for elastic membership (`MembershipView` /
//! `Coordinator::run_round(&view)`).
//!
//! Three invariants are pinned here:
//!
//! 1. **A full view is the frozen-fleet path, bit for bit.** Driving
//!    rounds with `next_view()` on a static fleet (no churn, no
//!    departures) lands on exactly the parameters of the
//!    `full_view()`-driven frozen-fleet reference — across all seven
//!    GARs, all three transport backends and every thread count.
//!    Elasticity costs nothing until a worker actually leaves.
//! 2. **Scripted churn is deterministic.** A leave-then-rejoin schedule
//!    produces bit-identical parameters on all three transports and
//!    every thread count, shrinks collection to the active fleet
//!    (never waiting out the timeout for a scripted absentee), and
//!    fires the membership metrics — two view changes, and one
//!    deliberate `ResilientMomentum` re-zero per shape change.
//! 3. **View misuse is a hard error, not a silent degradation**: stale
//!    round numbers, an `f` mismatch, a shrink below the GAR's
//!    `min_n(f)` quorum, and a shrunken view in grouped mode all
//!    refuse to run.

use multibulyan::attacks::AttackKind;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::{launch, MembershipView};
use multibulyan::gar::{GarKind, StageSpec};
use multibulyan::transport::TransportKind;

const TRANSPORTS: [TransportKind; 3] = [
    TransportKind::Threaded,
    TransportKind::Pooled,
    TransportKind::Socket,
];

fn base_exp(
    gar: GarKind,
    pre: Vec<StageSpec>,
    transport: TransportKind,
    threads: usize,
) -> ExperimentConfig {
    ExperimentConfig {
        cluster: ClusterConfig {
            n: 7,
            f: 1,
            actual_byzantine: Some(1),
            ..Default::default()
        },
        gar,
        pre,
        attack: AttackKind::SignFlip { scale: 5.0 },
        model: ModelConfig::Quadratic {
            dim: 48,
            noise: 0.3,
        },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.0,
            steps: 2,
            batch_size: 8,
            eval_every: 0,
            seed: 23,
        },
        threads,
        transport,
        collect: Default::default(),
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    }
}

#[test]
fn full_view_reproduces_the_frozen_fleet_path_for_every_gar() {
    // n = 7, f = 1 admits every rule (bulyan's 4f+3 = 7 is the tightest
    // quorum). The reference run drives `full_view()` — the frozen-fleet
    // path by construction; every other run drives `next_view()` — the
    // elastic entry, which on a static fleet must resolve to the same
    // full view and the same bits.
    for gar in GarKind::ALL {
        let reference = {
            let exp = base_exp(gar, Vec::new(), TransportKind::Pooled, 1);
            let cluster = launch(&exp, None).unwrap();
            let mut coordinator = cluster.coordinator;
            for _ in 0..2 {
                let view = coordinator.full_view();
                coordinator.run_round(&view).unwrap();
            }
            let params = coordinator.params().to_vec();
            coordinator.shutdown();
            params
        };
        for transport in TRANSPORTS {
            for threads in [1usize, 2, 4] {
                let exp = base_exp(gar, Vec::new(), transport, threads);
                let cluster = launch(&exp, None).unwrap();
                let mut coordinator = cluster.coordinator;
                for _ in 0..2 {
                    let view = coordinator.next_view();
                    // Static fleet: the elastic entry and the frozen
                    // fleet see the very same view.
                    assert_eq!(view, coordinator.full_view());
                    coordinator.run_round(&view).unwrap();
                }
                assert_eq!(
                    coordinator.metrics.counter("membership_view_changes"),
                    0,
                    "{gar} {transport} threads={threads}: static fleet \
                     must record no view change"
                );
                assert_eq!(
                    reference,
                    coordinator.params(),
                    "{gar} {transport} threads={threads}: next_view() run \
                     diverged from the frozen-fleet reference"
                );
                coordinator.shutdown();
            }
        }
    }
}

/// n = 9, f = 1, no actual attackers: workers 0 and 1 leave at round 3
/// and rejoin at round 5 (low ids leave — see `ChurnModel`). Krum's
/// quorum 2f+3 = 5 holds at the shrunken n' = 7.
fn churn_exp(transport: TransportKind, threads: usize) -> ExperimentConfig {
    let mut exp = base_exp(
        GarKind::Krum,
        vec![StageSpec::ResilientMomentum { beta: 0.9 }],
        transport,
        threads,
    );
    exp.cluster.n = 9;
    exp.cluster.actual_byzantine = Some(0);
    exp.cluster.churn_leave_round = 3;
    exp.cluster.churn_workers = 2;
    exp.cluster.churn_rejoin_round = 5;
    exp.attack = AttackKind::None;
    exp
}

#[test]
fn scripted_churn_shrinks_rejoins_and_stays_bit_identical_across_backends() {
    let mut reference: Option<Vec<f32>> = None;
    for transport in TRANSPORTS {
        for threads in [1usize, 2, 4] {
            let exp = churn_exp(transport, threads);
            let cluster = launch(&exp, None).unwrap();
            let mut coordinator = cluster.coordinator;
            for round in 1..=6u64 {
                let view = coordinator.next_view();
                let expected_active = if (3..5).contains(&round) { 7 } else { 9 };
                assert_eq!(
                    view.active(),
                    expected_active,
                    "{transport} threads={threads} round {round}"
                );
                let out = coordinator.run_round(&view).unwrap();
                // Collection tracks the view: a scripted absentee is not
                // waited for (no timeout expiry, no missing slot).
                assert_eq!(out.collected, expected_active);
                assert_eq!(out.missing, 0);
                // Selected ids are members (original ids, never
                // renumbered): workers 0 and 1 are not selectable while
                // absent.
                for w in &out.selected {
                    assert!(view.contains(*w), "round {round} selected non-member {w}");
                }
            }
            // Shrink + regrow = two view changes, and each shape change
            // deliberately re-zeros the ResilientMomentum state.
            assert_eq!(coordinator.metrics.counter("membership_view_changes"), 2);
            assert_eq!(coordinator.metrics.counter("membership_rezeros"), 2);
            let params = coordinator.params().to_vec();
            assert!(params.iter().all(|v| v.is_finite()));
            coordinator.shutdown();
            match &reference {
                None => reference = Some(params),
                Some(r) => assert_eq!(
                    r, &params,
                    "{transport} threads={threads}: churn run diverged \
                     (the elastic re-shard must be a pure function of the \
                      view, independent of backend and thread count)"
                ),
            }
        }
    }
}

#[test]
fn view_misuse_is_a_hard_error() {
    // MultiKrum n = 7, f = 1: quorum min_n = 2f+3 = 5.
    let exp = base_exp(GarKind::MultiKrum, Vec::new(), TransportKind::Pooled, 1);
    let cluster = launch(&exp, None).unwrap();
    let mut coordinator = cluster.coordinator;

    // Stale round number.
    let view = coordinator.next_view();
    coordinator.run_round(&view).unwrap();
    let err = coordinator.run_round(&view).unwrap_err().to_string();
    assert!(err.contains("round"), "stale view: {err}");

    // Declared-f mismatch.
    let mut view = coordinator.next_view();
    view.f = 2;
    let err = coordinator.run_round(&view).unwrap_err().to_string();
    assert!(err.contains("f = 2"), "f mismatch: {err}");

    // Quorum violation: 3 active + 1 byz = 4 < min_n = 5.
    let mut view = coordinator.next_view();
    view.workers.truncate(3);
    let err = coordinator.run_round(&view).unwrap_err().to_string();
    assert!(err.contains("min_n"), "quorum violation: {err}");

    // Malformed view: not strictly ascending.
    let mut view = coordinator.next_view();
    view.workers.swap(0, 1);
    assert!(coordinator.run_round(&view).is_err());
    coordinator.shutdown();

    // Grouped mode admits only full views.
    let mut exp = base_exp(GarKind::TrimmedMean, Vec::new(), TransportKind::Pooled, 1);
    exp.cluster.n = 12;
    exp.cluster.f = 1;
    exp.cluster.actual_byzantine = Some(0);
    exp.attack = AttackKind::None;
    exp.groups = 3;
    let cluster = launch(&exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    let mut view = coordinator.next_view();
    view.workers.pop();
    let err = coordinator.run_round(&view).unwrap_err().to_string();
    assert!(err.contains("full membership view"), "grouped shrink: {err}");
    let full = coordinator.next_view();
    assert!(MembershipView::full(full.round, 12, 1) == full);
    coordinator.run_round(&full).unwrap();
    coordinator.shutdown();
}
