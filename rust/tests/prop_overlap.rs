//! Bit-identity properties of the streaming prefix-combine round
//! (`overlap = "prefix"`).
//!
//! The hard invariant: for the same seeded cluster, `overlap = prefix`
//! produces the same `Selection` and bit-identical parameters as
//! `overlap = off` — the round matrix is frozen at the first-m quorum and
//! the combine+update arithmetic is coordinate-local, so the overlap
//! chunk grid is just another partition of `0..d`. The property is
//! exercised for all seven GARs and the `rmom(β)+rule` pipelines, under
//! a decisive straggler cost model and under malformed gradients, across
//! thread counts.
//!
//! The one *deliberate* behavioural difference — a straggler that
//! finishes inside the overlap window is salvaged into the last-good
//! cache instead of being thrown away — is pinned down by
//! `late_gradient_lands_in_cache_and_never_perturbs_the_current_round`:
//! the current round is untouched (that is the invariant), and the
//! salvage only shows up as a fresher fallback in *later* rounds.

use multibulyan::attacks::AttackKind;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::{launch, Coordinator, CoordinatorOptions, OverlapMode};
use multibulyan::data::QuadraticProblem;
use multibulyan::gar::{GarKind, StageSpec};
use multibulyan::runtime::Parallelism;
use multibulyan::training::LrSchedule;
use multibulyan::transport::{
    build, CollectMode, ComputeCost, Emitter, FaultModel, TransportKind, WorkerBody,
};
use multibulyan::worker::{GradSource, GradWorker};
use std::sync::Arc;
use std::time::Duration;

/// First-m experiment with a decisive straggler tail: the two stragglers
/// cost 15 ms per round, far beyond both the fast tier (300 µs) and the
/// prefix late-acceptance window (≤ a few 50 µs slices at d = 6000), so
/// the collected set, the straggler cache, and therefore every round's
/// parameters are identical whichever overlap mode runs.
fn overlap_exp(
    gar: GarKind,
    pre: Vec<StageSpec>,
    overlap: OverlapMode,
    threads: usize,
) -> ExperimentConfig {
    let f = 2;
    ExperimentConfig {
        cluster: ClusterConfig {
            n: 11,
            f,
            actual_byzantine: Some(2),
            round_timeout_ms: 60_000,
            compute_cost_us: 300,
            stragglers: 2,
            straggler_factor: 50.0,
            ..Default::default()
        },
        gar,
        pre,
        attack: AttackKind::SignFlip { scale: 5.0 },
        model: ModelConfig::Quadratic {
            dim: 6_000,
            noise: 0.3,
        },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.0,
            steps: 5,
            batch_size: 8,
            eval_every: 0,
            seed: 11,
        },
        threads,
        transport: TransportKind::Pooled,
        collect: CollectMode::FirstM,
        overlap,
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    }
}

fn run_overlap_exp(exp: &ExperimentConfig) -> (Vec<f32>, Vec<(usize, usize)>, u64) {
    let cluster = launch(exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    let mut outcomes = Vec::new();
    let mut saved = 0u64;
    for _ in 0..exp.train.steps {
        let view = coordinator.next_view();
        let out = coordinator.run_round(&view).unwrap();
        outcomes.push((out.collected, out.missing));
        saved += out.overlap_saved_us;
    }
    let params = coordinator.params().to_vec();
    coordinator.shutdown();
    (params, outcomes, saved)
}

#[test]
fn prefix_overlap_is_bit_identical_for_all_gars_and_pipelines() {
    let pipelines: [Vec<StageSpec>; 2] = [
        Vec::new(),
        vec![StageSpec::ResilientMomentum { beta: 0.5 }],
    ];
    for gar in GarKind::ALL {
        for pre in &pipelines {
            let (p_off, out_off, saved_off) =
                run_overlap_exp(&overlap_exp(gar, pre.clone(), OverlapMode::Off, 1));
            assert_eq!(saved_off, 0, "{gar}: off must never report overlap");
            for threads in [1usize, 2] {
                let (p_prefix, out_prefix, _saved) = run_overlap_exp(&overlap_exp(
                    gar,
                    pre.clone(),
                    OverlapMode::Prefix,
                    threads,
                ));
                assert_eq!(
                    out_off, out_prefix,
                    "{gar} pre={pre:?} threads={threads}: collected/missing diverged"
                );
                assert_eq!(
                    p_off, p_prefix,
                    "{gar} pre={pre:?} threads={threads}: prefix overlap changed the model"
                );
            }
        }
    }
}

#[test]
fn overlap_window_is_a_pure_pacing_knob() {
    // `overlap_window` (combine chunks claimed per drive slice) only
    // re-buckets the same fixed chunk grid — every value must land on
    // the same collected/missing counts and bit-identical parameters.
    let (p_off, out_off, _) = run_overlap_exp(&overlap_exp(
        GarKind::MultiBulyan,
        Vec::new(),
        OverlapMode::Off,
        2,
    ));
    for window in [1usize, 2, 16, 1024] {
        let mut exp = overlap_exp(GarKind::MultiBulyan, Vec::new(), OverlapMode::Prefix, 2);
        exp.overlap_window = window;
        let (p_w, out_w, _) = run_overlap_exp(&exp);
        assert_eq!(out_off, out_w, "window={window}: collected/missing diverged");
        assert_eq!(p_off, p_w, "window={window} changed the model");
    }
}

#[test]
fn prefix_overlap_reports_salvaged_drive_time_on_stragglers() {
    // The rules whose first-m quorum leaves the stragglers running (f=2
    // rules: quorum = 7 of 9 honest) must report a nonzero
    // overlap_saved_us — the drive progress made during the combine tail.
    let (_p, _o, saved) = run_overlap_exp(&overlap_exp(
        GarKind::MultiBulyan,
        Vec::new(),
        OverlapMode::Prefix,
        2,
    ));
    assert!(saved > 0, "stragglers were running; the window must overlap");
}

#[test]
fn prefix_overlap_on_threaded_transport_falls_back_to_off() {
    // The streaming prefix-combine needs the pooled time-sliced drive;
    // on the threaded backend the knob must be a no-op, not an error.
    let run = |overlap: OverlapMode| -> (Vec<f32>, u64) {
        let mut exp = overlap_exp(GarKind::MultiKrum, Vec::new(), overlap, 2);
        exp.transport = TransportKind::Threaded;
        let (params, _outcomes, saved) = run_overlap_exp(&exp);
        (params, saved)
    };
    let (p_off, _) = run(OverlapMode::Off);
    let (p_prefix, saved) = run(OverlapMode::Prefix);
    assert_eq!(p_off, p_prefix);
    assert_eq!(saved, 0, "threaded has no virtual drive to overlap");
}

/// A worker that instantly emits a wrong-length gradient every round.
struct BadLenBody;
impl WorkerBody for BadLenBody {
    fn on_round(&mut self, round: u64, _p: &[f32], emit: &mut Emitter<'_>) {
        emit.send(round, &[1.0, 2.0]); // d is 6000 below
    }
}

#[test]
fn prefix_overlap_is_bit_identical_under_malformed_gradients() {
    // n = 9, f = 3, first-m quorum m = 6. Worker 8 is a fast bad actor
    // (wrong-length gradient), workers 0–1 are 40× stragglers: the
    // quorum must fill from the six well-formed fast workers (2–7) on
    // both paths, the bad actor's rejected submission must not occupy a
    // slot, and the stragglers (12 ms ≫ the ≤ 100 µs window at
    // d = 6000) must never reach the cache.
    let d = 6_000;
    let run = |overlap: OverlapMode| -> (Vec<f32>, Vec<(usize, usize)>) {
        let problem = Arc::new(QuadraticProblem::new(d, 0.3, 5));
        let faults = FaultModel {
            cost: ComputeCost {
                base_us: 300,
                slow_workers: 2,
                slow_factor: 40.0,
            },
            ..Default::default()
        };
        let (server, workers) = build(TransportKind::Pooled, 9, faults, &Parallelism::new(2));
        for (i, ep) in workers.into_iter().enumerate() {
            if i == 8 {
                ep.serve(BadLenBody);
            } else {
                ep.serve(GradWorker::new(GradSource::quadratic(
                    Arc::clone(&problem),
                    i,
                    8,
                )));
            }
        }
        let mut coord = Coordinator::builder(GarKind::MultiKrum.instantiate(9, 3).unwrap())
            .options(CoordinatorOptions {
                round_timeout: Duration::from_secs(10),
                schedule: LrSchedule::Fixed { base: 0.1 },
                seed: 7,
                collect: CollectMode::FirstM,
                overlap,
                overlap_window: 1,
                ..Default::default()
            })
            .build(server, vec![0.0; d], 0.1, 0.0)
            .unwrap();
        let mut outcomes = Vec::new();
        for _ in 0..3 {
            let view = coord.next_view();
            let out = coord.run_round(&view).unwrap();
            outcomes.push((out.collected, out.missing));
        }
        let params = coord.params().to_vec();
        coord.shutdown();
        (params, outcomes)
    };
    let (p_off, out_off) = run(OverlapMode::Off);
    let (p_prefix, out_prefix) = run(OverlapMode::Prefix);
    // Quorum = the 6 well-formed fast workers; 3 missing (2 stragglers +
    // the bad actor) every round, on both paths.
    assert!(out_off.iter().all(|&(c, m)| c == 6 && m == 3), "{out_off:?}");
    assert_eq!(out_off, out_prefix);
    assert_eq!(p_off, p_prefix, "malformed handling diverged under overlap");
}

#[test]
fn late_gradient_lands_in_cache_and_never_perturbs_the_current_round() {
    // n = 7, f = 1, first-m quorum m = 6 = exactly the fast tier; the
    // one straggler (1.2 ms) finishes *inside* the prefix window
    // (20 chunks at d = 80 000 ⇒ up to 1 ms of extra drive after the
    // 300 µs quorum). Its late gradient must land in the last-good cache
    // — round 1 stays bit-identical to overlap = off — and only surface
    // as the round-2 fallback, where overlap = off would have used a
    // zero row. The GAR is coordinate-wise (trimmed-mean) so the
    // fallback row's values reach the round-2 aggregate directly: a
    // zero row and the salvaged stale gradient cannot produce the same
    // parameters.
    let exp = |overlap: OverlapMode| -> ExperimentConfig {
        ExperimentConfig {
            cluster: ClusterConfig {
                n: 7,
                f: 1,
                actual_byzantine: Some(0),
                round_timeout_ms: 60_000,
                compute_cost_us: 300,
                stragglers: 1,
                straggler_factor: 4.0,
                ..Default::default()
            },
            gar: GarKind::TrimmedMean,
            pre: Vec::new(),
            attack: AttackKind::None,
            model: ModelConfig::Quadratic {
                dim: 80_000,
                noise: 0.3,
            },
            train: TrainConfig {
                learning_rate: 0.1,
                momentum: 0.0,
                steps: 2,
                batch_size: 8,
                eval_every: 0,
                seed: 13,
            },
            threads: 2,
            transport: TransportKind::Pooled,
            collect: CollectMode::FirstM,
            overlap,
            overlap_window: 1,
            codec: None,
            groups: 1,
            output_dir: None,
            journal: None,
            crash_after_round: None,
        }
    };
    let run = |overlap: OverlapMode| -> (Vec<f32>, Vec<f32>, u64, u64) {
        let cluster = launch(&exp(overlap), None).unwrap();
        let mut coordinator = cluster.coordinator;
        let view = coordinator.next_view();
        let r1 = coordinator.run_round(&view).unwrap();
        assert_eq!((r1.collected, r1.missing), (6, 1), "{overlap}");
        let after_r1 = coordinator.params().to_vec();
        let view = coordinator.next_view();
        let r2 = coordinator.run_round(&view).unwrap();
        assert_eq!((r2.collected, r2.missing), (6, 1), "{overlap}");
        let after_r2 = coordinator.params().to_vec();
        let late = coordinator.metrics.counter("gradients_late_cached");
        let saved = coordinator.metrics.counter("overlap_saved_us");
        coordinator.shutdown();
        (after_r1, after_r2, late, saved)
    };
    let (off_r1, off_r2, off_late, off_saved) = run(OverlapMode::Off);
    let (pre_r1, pre_r2, pre_late, pre_saved) = run(OverlapMode::Prefix);
    assert_eq!(off_late, 0);
    assert_eq!(off_saved, 0);
    // The current round is never perturbed by the late arrival…
    assert_eq!(off_r1, pre_r1, "round 1 must be bit-identical");
    // …which lands in the cache instead (once per round here: the
    // straggler finishes every round's gradient inside the window)…
    assert_eq!(pre_late, 2, "one salvaged gradient per round");
    assert!(pre_saved > 0);
    // …and surfaces only as the round-2 straggler fallback: off falls
    // back to a zero row, prefix to the salvaged round-1 gradient.
    assert_ne!(
        off_r2, pre_r2,
        "the salvaged cache entry must replace the zero fallback in round 2"
    );
}
