//! Property-based tests on GAR invariants (in-repo harness —
//! `multibulyan::util::proptest`; see Cargo.toml for why).
//!
//! The invariants are the algebraic facts the paper's proofs lean on:
//! permutation invariance (a GAR cannot depend on worker identity),
//! translation/scale equivariance (distances and medians commute with
//! affine maps), convex-hull confinement per coordinate for the median
//! family, and the resilience contracts under adversarial rows.

use multibulyan::gar::{
    pairwise_sq_distances_sharded, CombineScratch, Gar, GarKind, GarScratch, SHARD_D,
};
use multibulyan::runtime::Parallelism;
use multibulyan::tensor::GradMatrix;
use multibulyan::util::proptest::{check, default_cases};
use multibulyan::util::Rng64;

const N: usize = 11;
const F: usize = 2;

fn random_grads(rng: &mut Rng64, n: usize, d: usize, scale: f32) -> GradMatrix {
    GradMatrix::from_fn(n, d, |_, _| scale * rng.gaussian())
}

fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let err = (x - y).abs();
        let bound = tol * (1.0 + x.abs().max(y.abs()));
        if err > bound {
            return Err(format!("coord {i}: {x} vs {y} (err {err})"));
        }
    }
    Ok(())
}

/// Fisher–Yates shuffle of row indices.
fn shuffled_rows(rng: &mut Rng64, n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.gen_range_usize(i + 1);
        idx.swap(i, j);
    }
    idx
}

#[test]
fn permutation_invariance() {
    // Every rule must return the same aggregate when workers are
    // re-ordered (ties are measure-zero for gaussian inputs).
    for kind in GarKind::ALL {
        check(&format!("perm-invariance/{kind}"), default_cases(), |rng, _| {
            let d = 1 + rng.gen_range_usize(64);
            let grads = random_grads(rng, N, d, 1.0);
            let perm = shuffled_rows(rng, N);
            let shuffled = grads.gather_rows(&perm);
            let gar = kind.instantiate(N, F).unwrap();
            let a = gar.aggregate(&grads).map_err(|e| e.to_string())?;
            let b = gar.aggregate(&shuffled).map_err(|e| e.to_string())?;
            assert_close(&a, &b, 1e-4)
        });
    }
}

#[test]
fn translation_equivariance() {
    // GAR(G + c·1) = GAR(G) + c for every rule: distances and
    // per-coordinate order statistics are translation invariant.
    for kind in GarKind::ALL {
        check(&format!("translation/{kind}"), default_cases(), |rng, _| {
            let d = 1 + rng.gen_range_usize(48);
            let grads = random_grads(rng, N, d, 1.0);
            let shift = rng.gen_range_f32(-5.0, 5.0);
            let mut shifted = grads.clone();
            for v in shifted.flat_mut() {
                *v += shift;
            }
            let gar = kind.instantiate(N, F).unwrap();
            let a = gar.aggregate(&grads).map_err(|e| e.to_string())?;
            let b = gar.aggregate(&shifted).map_err(|e| e.to_string())?;
            let a_shift: Vec<f32> = a.iter().map(|v| v + shift).collect();
            assert_close(&a_shift, &b, 2e-3)
        });
    }
}

#[test]
fn scale_equivariance() {
    // GAR(a·G) = a·GAR(G) for positive a.
    for kind in GarKind::ALL {
        check(&format!("scale/{kind}"), default_cases(), |rng, _| {
            let d = 1 + rng.gen_range_usize(48);
            let grads = random_grads(rng, N, d, 1.0);
            let a = rng.gen_range_f32(0.1, 4.0);
            let mut scaled = grads.clone();
            for v in scaled.flat_mut() {
                *v *= a;
            }
            let gar = kind.instantiate(N, F).unwrap();
            let base = gar.aggregate(&grads).map_err(|e| e.to_string())?;
            let got = gar.aggregate(&scaled).map_err(|e| e.to_string())?;
            let want: Vec<f32> = base.iter().map(|v| v * a).collect();
            assert_close(&want, &got, 2e-3)
        });
    }
}

#[test]
fn coordinatewise_rules_stay_in_convex_hull() {
    // Median / trimmed-mean / bulyan-family outputs lie within the
    // per-coordinate min/max of ALL inputs (and of the correct inputs
    // when f rows are wild — checked in resilience tests).
    for kind in [
        GarKind::Median,
        GarKind::TrimmedMean,
        GarKind::Bulyan,
        GarKind::MultiBulyan,
        GarKind::Average,
        GarKind::MultiKrum,
        GarKind::Krum,
    ] {
        check(&format!("hull/{kind}"), default_cases(), |rng, _| {
            let d = 1 + rng.gen_range_usize(32);
            let grads = random_grads(rng, N, d, 2.0);
            let gar = kind.instantiate(N, F).unwrap();
            let out = gar.aggregate(&grads).map_err(|e| e.to_string())?;
            for j in 0..d {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for i in 0..N {
                    lo = lo.min(grads.row(i)[j]);
                    hi = hi.max(grads.row(i)[j]);
                }
                if out[j] < lo - 1e-4 || out[j] > hi + 1e-4 {
                    return Err(format!(
                        "coord {j}: {} outside [{lo}, {hi}]",
                        out[j]
                    ));
                }
            }
            Ok(())
        });
    }
}

#[test]
fn strong_rules_confined_by_correct_rows_under_wild_byzantines() {
    // With f wild rows, BULYAN-family outputs stay inside the correct
    // rows' per-coordinate range — the strong-resilience hull property.
    for kind in [GarKind::Bulyan, GarKind::MultiBulyan, GarKind::Median, GarKind::TrimmedMean] {
        check(&format!("byz-hull/{kind}"), default_cases(), |rng, _| {
            let d = 1 + rng.gen_range_usize(32);
            let mut grads = random_grads(rng, N, d, 1.0);
            let magnitude = 10f32.powf(rng.gen_range_f32(2.0, 8.0));
            for b in 0..F {
                let sign = if b % 2 == 0 { 1.0 } else { -1.0 };
                grads.row_mut(N - 1 - b).iter_mut().for_each(|v| *v = sign * magnitude);
            }
            let gar = kind.instantiate(N, F).unwrap();
            let out = gar.aggregate(&grads).map_err(|e| e.to_string())?;
            for j in 0..d {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for i in 0..N - F {
                    lo = lo.min(grads.row(i)[j]);
                    hi = hi.max(grads.row(i)[j]);
                }
                if out[j] < lo - 1e-3 || out[j] > hi + 1e-3 {
                    return Err(format!(
                        "coord {j}: {} escaped correct hull [{lo}, {hi}]",
                        out[j]
                    ));
                }
            }
            Ok(())
        });
    }
}

#[test]
fn krum_family_returns_a_correct_row_under_wild_byzantines() {
    // KRUM's output must be one of the correct gradients when the f
    // Byzantine rows are far away; MULTI-KRUM's must be an average of
    // correct rows (hence inside their hull).
    check("krum-selects-correct", default_cases(), |rng, _| {
        let d = 2 + rng.gen_range_usize(32);
        let mut grads = random_grads(rng, N, d, 0.5);
        for b in 0..F {
            grads
                .row_mut(N - 1 - b)
                .iter_mut()
                .for_each(|v| *v = 1e6 + *v);
        }
        let krum = GarKind::Krum.instantiate(N, F).unwrap();
        let out = krum.aggregate(&grads).map_err(|e| e.to_string())?;
        let is_correct_row = (0..N - F).any(|i| {
            grads
                .row(i)
                .iter()
                .zip(&out)
                .all(|(a, b)| (a - b).abs() < 1e-6)
        });
        if !is_correct_row {
            return Err("krum output is not a correct worker's row".into());
        }
        Ok(())
    });
}

#[test]
fn scratch_reuse_is_deterministic() {
    // Repeated aggregation with a shared scratch must be bit-identical —
    // a regression guard on buffer-reuse bugs.
    for kind in GarKind::ALL {
        check(&format!("scratch/{kind}"), 16, |rng, _| {
            let d = 1 + rng.gen_range_usize(64);
            let grads = random_grads(rng, N, d, 1.0);
            let gar = kind.instantiate(N, F).unwrap();
            let mut scratch = GarScratch::new();
            let mut out1 = vec![0.0; d];
            let mut out2 = vec![0.0; d];
            gar.aggregate_with_scratch(&grads, &mut out1, &mut scratch)
                .map_err(|e| e.to_string())?;
            // Interleave a different-shaped call to stress buffer resize.
            let other = random_grads(rng, N, (d / 2).max(1), 1.0);
            let mut tmp = vec![0.0; other.d()];
            gar.aggregate_with_scratch(&other, &mut tmp, &mut scratch)
                .map_err(|e| e.to_string())?;
            gar.aggregate_with_scratch(&grads, &mut out2, &mut scratch)
                .map_err(|e| e.to_string())?;
            if out1 != out2 {
                return Err("scratch reuse changed the result".into());
            }
            Ok(())
        });
    }
}

#[test]
fn parallel_output_bit_identical_to_sequential() {
    // The sharded parallel engine must be invisible: for every rule and
    // every (n, f, d, threads) the aggregate equals the sequential one
    // **bit for bit** (`==`, not approximately) — the contract that makes
    // `threads` a pure latency knob. Exercises small d (sharding disabled),
    // d around the coordinate-shard threshold, and adversarial ±1e30 rows
    // (whose squared distances overflow to +inf).
    for kind in GarKind::ALL {
        check(&format!("parallel-vs-seq/{kind}"), default_cases(), |rng, _| {
            let f = rng.gen_range_usize(3); // 0..=2
            let n = kind.min_n(f).max(3) + rng.gen_range_usize(6);
            // Mix tiny and shard-crossing dimensions.
            let d = match rng.gen_range_usize(3) {
                0 => 1 + rng.gen_range_usize(64),
                1 => 3_000 + rng.gen_range_usize(3_000),
                _ => 9_000 + rng.gen_range_usize(12_000),
            };
            let threads = 2 + rng.gen_range_usize(3); // 2..=4
            let mut grads = random_grads(rng, n, d, 1.0);
            if f > 0 && rng.gen_bool(0.5) {
                // Adversarial magnitude blow-up (the `infinity` attack).
                for b in 0..f {
                    let sign = if b % 2 == 0 { 1.0 } else { -1.0 };
                    grads
                        .row_mut(n - 1 - b)
                        .iter_mut()
                        .for_each(|v| *v = sign * 1e30);
                }
            }
            let seq = kind
                .instantiate_parallel(n, f, &Parallelism::sequential())
                .map_err(|e| e.to_string())?;
            let par = kind
                .instantiate_parallel(n, f, &Parallelism::new(threads))
                .map_err(|e| e.to_string())?;
            let a = seq.aggregate(&grads).map_err(|e| e.to_string())?;
            let b = par.aggregate(&grads).map_err(|e| e.to_string())?;
            if a != b {
                let diverged = a
                    .iter()
                    .zip(&b)
                    .position(|(x, y)| x != y)
                    .unwrap_or(usize::MAX);
                return Err(format!(
                    "n={n} f={f} d={d} threads={threads}: first divergence at coord {diverged}"
                ));
            }
            // Scratch-reuse path must agree with the allocating path too.
            let mut scratch = GarScratch::new();
            let mut c = vec![0.0f32; d];
            par.aggregate_with_scratch(&grads, &mut c, &mut scratch)
                .map_err(|e| e.to_string())?;
            if b != c {
                return Err("parallel scratch reuse changed the result".into());
            }
            Ok(())
        });
    }
}

#[test]
fn select_combine_partition_bit_identical_to_aggregate() {
    // The two-phase contract: `select` once, then `combine` over an
    // ARBITRARY partition of 0..d into contiguous ranges, must reproduce
    // the one-shot aggregate bit for bit — for all seven rules, including
    // under adversarial ±1e30 rows. This is what licenses the
    // coordinator's fused combine+update pass.
    for kind in GarKind::ALL {
        check(&format!("select-combine/{kind}"), default_cases(), |rng, _| {
            let f = rng.gen_range_usize(3); // 0..=2
            let n = kind.min_n(f).max(3) + rng.gen_range_usize(6);
            let d = 1 + rng.gen_range_usize(3_000);
            let mut grads = random_grads(rng, n, d, 1.0);
            if f > 0 && rng.gen_bool(0.5) {
                for b in 0..f {
                    let sign = if b % 2 == 0 { 1.0 } else { -1.0 };
                    grads
                        .row_mut(n - 1 - b)
                        .iter_mut()
                        .for_each(|v| *v = sign * 1e30);
                }
            }
            let gar = kind.instantiate(n, f).map_err(|e| e.to_string())?;
            let reference = gar.aggregate(&grads).map_err(|e| e.to_string())?;
            let mut scratch = GarScratch::new();
            let sel = gar.select(&grads, &mut scratch).map_err(|e| e.to_string())?;
            if sel.selected_rows().is_empty() || sel.selected_rows().iter().any(|&r| r >= n) {
                return Err("selection rows out of range".into());
            }
            // Random partition into contiguous ranges (often length 1).
            let mut out = vec![0.0f32; d];
            let mut cs = CombineScratch::default();
            let mut start = 0usize;
            while start < d {
                let max_len = d - start;
                let len = 1 + rng.gen_range_usize(max_len.min(257));
                gar.combine(&sel, &grads, start, &mut out[start..start + len], &mut cs)
                    .map_err(|e| e.to_string())?;
                start += len;
            }
            if out != reference {
                let diverged = out
                    .iter()
                    .zip(&reference)
                    .position(|(x, y)| x != y)
                    .unwrap_or(usize::MAX);
                return Err(format!(
                    "n={n} f={f} d={d}: partitioned combine diverged at coord {diverged}"
                ));
            }
            Ok(())
        });
    }
}

#[test]
fn pairwise_tree_reduction_bit_identical_at_large_n() {
    // The ISSUE/ROADMAP item behind the tree reduction: at n ∈ {64, 131}
    // the chunk-partial reduction must stay bit-identical across thread
    // counts (the tree shape depends only on d, never on threads). d
    // crosses several SHARD_D chunk boundaries so the tree has real depth.
    for (n, d) in [(64usize, 2 * SHARD_D + 517), (131, SHARD_D + 13)] {
        let g = GradMatrix::from_fn(n, d, |i, j| ((i * 131 + j) % 251) as f32 * 0.013 - 1.5);
        let mut seq = vec![0.0f32; n * n];
        let mut partials = Vec::new();
        pairwise_sq_distances_sharded(&g, &mut seq, &Parallelism::sequential(), &mut partials);
        for threads in [2usize, 4] {
            let par = Parallelism::new(threads);
            let mut out = vec![0.0f32; n * n];
            let mut scratch = Vec::new();
            pairwise_sq_distances_sharded(&g, &mut out, &par, &mut scratch);
            assert_eq!(seq, out, "n={n} d={d} threads={threads}");
        }
    }
}

#[test]
fn gradients_used_matches_theory() {
    // m̃ accounting used by the slowdown analysis.
    let cases: Vec<(GarKind, usize)> = vec![
        (GarKind::Average, N),
        (GarKind::Median, 1),
        (GarKind::Krum, 1),
        (GarKind::MultiKrum, N - F - 2),
        (GarKind::Bulyan, N - 2 * F - 2 - 2 * F),
        (GarKind::MultiBulyan, N - 2 * F - 2),
        (GarKind::TrimmedMean, N - 2 * F),
    ];
    for (kind, want) in cases {
        let gar = kind.instantiate(N, F).unwrap();
        assert_eq!(gar.gradients_used(), want, "{kind}");
    }
}
