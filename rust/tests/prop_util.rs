//! Property tests for the in-repo substrates (JSON parser, config
//! parser, PRNG, selection primitives) — the code everything else trusts.

use multibulyan::tensor::{argselect_smallest, coordinate_median, select_k_smallest};
use multibulyan::util::json::Json;
use multibulyan::util::proptest::{check, default_cases};
use multibulyan::util::Rng64;

/// Generate a random JSON value of bounded depth.
fn random_json(rng: &mut Rng64, depth: usize) -> Json {
    let choice = if depth == 0 {
        rng.gen_range_usize(4)
    } else {
        rng.gen_range_usize(6)
    };
    match choice {
        0 => Json::Null,
        1 => Json::Bool(rng.gen_bool(0.5)),
        2 => {
            // Integers round-trip exactly; that's what manifests use.
            Json::Num(rng.gen_range_i64(-1_000_000, 1_000_000) as f64)
        }
        3 => {
            let len = rng.gen_range_usize(12);
            let s: String = (0..len)
                .map(|_| {
                    // Mix of ASCII, escapes and multibyte.
                    match rng.gen_range_usize(6) {
                        0 => '"',
                        1 => '\\',
                        2 => '\n',
                        3 => 'π',
                        4 => char::from(b'a' + rng.gen_range_usize(26) as u8),
                        _ => char::from(b'0' + rng.gen_range_usize(10) as u8),
                    }
                })
                .collect();
            Json::Str(s)
        }
        4 => {
            let len = rng.gen_range_usize(4);
            Json::Arr((0..len).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let len = rng.gen_range_usize(4);
            let mut m = std::collections::BTreeMap::new();
            for i in 0..len {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn json_roundtrip_random_documents() {
    check("json-roundtrip", default_cases() * 4, |rng, _| {
        let doc = random_json(rng, 3);
        let text = doc.to_string_compact();
        let back = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
        if back != doc {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

#[test]
fn json_parser_never_panics_on_garbage() {
    check("json-no-panic", default_cases() * 4, |rng, _| {
        let len = rng.gen_range_usize(40);
        let garbage: String = (0..len)
            .map(|_| {
                let pool = b"{}[]\",:0123456789truefalsenul \\\n";
                char::from(pool[rng.gen_range_usize(pool.len())])
            })
            .collect();
        // Must return Ok or Err, never panic.
        let _ = Json::parse(&garbage);
        Ok(())
    });
}

#[test]
fn argselect_agrees_with_full_sort() {
    check("argselect-vs-sort", default_cases() * 2, |rng, _| {
        let n = 1 + rng.gen_range_usize(40);
        let k = rng.gen_range_usize(n + 1);
        let scores: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
        let picked = argselect_smallest(&scores, k);
        if picked.len() != k {
            return Err(format!("len {} != {k}", picked.len()));
        }
        let mut sorted = scores.clone();
        sorted.sort_by(f32::total_cmp);
        // Values (not indices: ties) must match the k smallest.
        for (i, &p) in picked.iter().enumerate() {
            if scores[p] != sorted[i] {
                return Err(format!("rank {i}: {} != {}", scores[p], sorted[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn select_k_values_are_a_multiset_subset() {
    check("select-k-multiset", default_cases(), |rng, _| {
        let n = 1 + rng.gen_range_usize(30);
        let k = rng.gen_range_usize(n + 1);
        let values: Vec<f32> = (0..n).map(|_| rng.gen_range_f32(-10.0, 10.0)).collect();
        let picked = select_k_smallest(&values, k);
        let mut pool = values;
        for v in picked {
            match pool.iter().position(|&x| x == v) {
                Some(i) => {
                    pool.swap_remove(i);
                }
                None => return Err(format!("{v} not in input")),
            }
        }
        Ok(())
    });
}

#[test]
fn median_is_order_statistic() {
    check("median-order-stat", default_cases(), |rng, _| {
        let n = 1 + rng.gen_range_usize(25);
        let values: Vec<f32> = (0..n).map(|_| rng.gaussian()).collect();
        let med = coordinate_median(&values);
        let below = values.iter().filter(|&&v| v <= med + 1e-6).count();
        let above = values.iter().filter(|&&v| v >= med - 1e-6).count();
        if below * 2 < n || above * 2 < n {
            return Err(format!("median {med} splits {below}/{above} of {n}"));
        }
        Ok(())
    });
}

#[test]
fn config_parser_never_panics_on_garbage() {
    check("config-no-panic", default_cases() * 2, |rng, _| {
        let len = rng.gen_range_usize(60);
        let pool = b"[]= \"\nabc0.5#_x";
        let garbage: String = (0..len)
            .map(|_| char::from(pool[rng.gen_range_usize(pool.len())]))
            .collect();
        let _ = multibulyan::config::parser::parse(&garbage);
        Ok(())
    });
}

#[test]
fn rng_streams_reproducible_and_distinct() {
    check("rng-streams", 8, |rng, case| {
        let seed = rng.next_u64();
        let mut a = Rng64::seed_from_u64(seed);
        let mut b = Rng64::seed_from_u64(seed);
        let mut c = Rng64::seed_from_u64(seed ^ (case + 1));
        let mut same_c = 0;
        for _ in 0..64 {
            let x = a.next_u64();
            if x != b.next_u64() {
                return Err("same seed diverged".into());
            }
            if x == c.next_u64() {
                same_c += 1;
            }
        }
        if same_c > 0 {
            return Err("different seeds collided".into());
        }
        Ok(())
    });
}
