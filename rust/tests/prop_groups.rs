//! Property tests for the two-level grouped aggregation hierarchy
//! (`groups = g` / a leading `group(g)` pipeline stage).
//!
//! Three invariants are pinned here:
//!
//! 1. **`groups = 1` is the flat path.** Spelling the knob explicitly
//!    routes through exactly the single-level coordinator, so for every
//!    GAR, pipeline, transport backend and thread count the parameters
//!    are bit-identical to the flag-absent run.
//! 2. **Grouped collection is deterministic.** The group reduction is a
//!    fixed positional pairwise tree per 4096-coordinate block, so the
//!    same seeded run lands on bit-identical parameters on all three
//!    transports (server-side full-vector ingest on `threaded`,
//!    transport-side ingest on `pooled`, chunk-level streaming ingest on
//!    `socket`) and for every thread count.
//! 3. **The hierarchy still trains under attack**, with the scaled root
//!    Byzantine bound f_root = ⌈f·g/n⌉, and selection metrics attribute
//!    through group provenance back to underlying worker ids.
//!
//! The streamed-memory bound itself (`peak_resident_floats` ≪ n×d) is
//! unit-tested next to the reducer (`gar::group`); here the same
//! high-water mark is asserted end-to-end through the
//! `group_reducer_peak_floats` metrics counter.

use multibulyan::attacks::AttackKind;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::launch;
use multibulyan::gar::{GarKind, StageSpec};
use multibulyan::transport::TransportKind;

const TRANSPORTS: [TransportKind; 3] = [
    TransportKind::Threaded,
    TransportKind::Pooled,
    TransportKind::Socket,
];

fn base_exp(
    gar: GarKind,
    pre: Vec<StageSpec>,
    transport: TransportKind,
    threads: usize,
    groups: usize,
    dim: usize,
) -> ExperimentConfig {
    ExperimentConfig {
        cluster: ClusterConfig {
            n: 11,
            f: 2,
            actual_byzantine: Some(2),
            ..Default::default()
        },
        gar,
        pre,
        attack: AttackKind::SignFlip { scale: 5.0 },
        model: ModelConfig::Quadratic { dim, noise: 0.3 },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.0,
            steps: 2,
            batch_size: 8,
            eval_every: 0,
            seed: 17,
        },
        threads,
        transport,
        collect: Default::default(),
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    }
}

/// Launch, run `steps` rounds, return (params, reducer peak floats).
fn run_rounds(exp: &ExperimentConfig, steps: usize) -> (Vec<f32>, u64) {
    let cluster = launch(exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    for _ in 0..steps {
        let view = coordinator.next_view();
        let out = coordinator.run_round(&view).unwrap();
        assert_eq!(out.missing, 0, "no worker may go missing in these runs");
    }
    let params = coordinator.params().to_vec();
    let peak = coordinator.metrics.counter("group_reducer_peak_floats");
    coordinator.shutdown();
    (params, peak)
}

#[test]
fn groups_of_one_is_bit_identical_to_flat_for_every_gar_and_pipeline() {
    // The knob's identity case: `groups = 1` must be the flat
    // single-level path, bit for bit — across all seven GARs, with and
    // without a pre-aggregation stage, on every transport backend and
    // thread count (transports/threads stay pure latency knobs).
    let pipelines: [Vec<StageSpec>; 2] = [
        Vec::new(),
        vec![StageSpec::ResilientMomentum { beta: 0.9 }],
    ];
    for gar in GarKind::ALL {
        for pre in &pipelines {
            let (reference, ref_peak) = run_rounds(
                &base_exp(gar, pre.clone(), TransportKind::Pooled, 1, 1, 48),
                2,
            );
            assert_eq!(ref_peak, 0, "{gar}: flat path must never touch the reducer");
            for transport in TRANSPORTS {
                for threads in [1usize, 2, 4] {
                    let (params, peak) =
                        run_rounds(&base_exp(gar, pre.clone(), transport, threads, 1, 48), 2);
                    assert_eq!(peak, 0, "{gar} {transport} threads={threads}");
                    assert_eq!(
                        reference, params,
                        "{gar} pre={pre:?} {transport} threads={threads}: \
                         groups=1 diverged from flat"
                    );
                }
            }
        }
    }
}

/// n=12, f=1, byz=1, g=4: one forged group row (⌈1·4/12⌉), three honest
/// groups of 4/4/3 workers, root trimmed-mean with f_root = 1 over the 4
/// group rows. d spans three 4096-blocks and the socket chunk is shrunk
/// to 2048 so the streaming reassembly path (multiple GradientChunk
/// frames per block) is genuinely exercised.
fn grouped_exp(transport: TransportKind, threads: usize) -> ExperimentConfig {
    let mut exp = base_exp(GarKind::TrimmedMean, Vec::new(), transport, threads, 4, 10_000);
    exp.cluster.n = 12;
    exp.cluster.f = 1;
    exp.cluster.actual_byzantine = Some(1);
    exp.cluster.socket_chunk = 2_048;
    exp
}

#[test]
fn grouped_aggregation_is_bit_identical_across_transports_and_thread_counts() {
    let mut reference: Option<Vec<f32>> = None;
    for transport in TRANSPORTS {
        for threads in [1usize, 2, 4] {
            let (params, peak) = run_rounds(&grouped_exp(transport, threads), 3);
            // The streamed-memory bound, end to end: even the transient
            // high-water mark (live tree partials + staged chunks) stays
            // under the 11×10 000-float flat honest matrix. At this tiny
            // n the tree's constant factors dominate — the sharp
            // O(g·d·log s + n·block) budget is pinned at n = 512 in
            // `gar::group::tests::arena_accounting_never_approaches_the_flat_matrix`.
            assert!(peak > 0, "{transport} threads={threads}: reducer never ran");
            assert!(
                peak < 110_000,
                "{transport} threads={threads}: reducer peak {peak} floats \
                 reaches the flat n×d matrix"
            );
            match &reference {
                None => reference = Some(params),
                Some(r) => assert_eq!(
                    r, &params,
                    "{transport} threads={threads}: grouped run diverged \
                     from the reference (group reduction must be a fixed \
                     positional pairwise tree, independent of backend, \
                     arrival order and thread count)"
                ),
            }
        }
    }
}

#[test]
fn grouped_pipeline_spelling_matches_the_root_key() {
    // `--gar 'group(4)+trimmed-mean'` and `groups = 4` are the same knob.
    let (via_key, _) = run_rounds(&grouped_exp(TransportKind::Pooled, 2), 3);
    let mut exp = grouped_exp(TransportKind::Pooled, 2);
    exp.groups = 1;
    exp.pre.insert(0, StageSpec::GroupAggregate { groups: 4 });
    let (via_stage, _) = run_rounds(&exp, 3);
    assert_eq!(via_key, via_stage);
}

#[test]
fn grouped_hierarchy_trains_through_a_byzantine_attack() {
    // n=16, f=2, byz=2, g=8: the two attackers fill ⌈2·8/16⌉ = 1 forged
    // group row; f_root = 1 keeps multi-bulyan's 4f+3 = 7 ≤ 8 quorum.
    let mut exp = base_exp(GarKind::MultiBulyan, Vec::new(), TransportKind::Pooled, 2, 8, 300);
    exp.cluster.n = 16;
    exp.cluster.f = 2;
    exp.cluster.actual_byzantine = Some(2);
    exp.model = ModelConfig::Quadratic {
        dim: 300,
        noise: 0.1,
    };
    exp.train.steps = 30;
    exp.train.eval_every = 1;
    let cluster = launch(&exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    let mut evaluator = cluster.evaluator;
    coordinator
        .train(exp.train.steps, exp.train.eval_every, &mut evaluator)
        .unwrap();
    let curve = coordinator.metrics.curve();
    assert!(curve.len() >= 2, "eval_every=1 must record a curve");
    let (first, last) = (curve[0].loss, curve[curve.len() - 1].loss);
    assert!(
        last.is_finite() && last < first,
        "grouped multi-bulyan failed to train through sign-flip: \
         loss {first} → {last}"
    );
    // Selection metrics attribute through group provenance to underlying
    // worker ids: the recorder is sized for all n=16 workers and honest
    // workers (ids 0..13, the non-trailing groups) accrue selections.
    let selections = coordinator.metrics.selections().to_vec();
    assert_eq!(selections.len(), 16);
    assert!(
        selections.iter().take(14).any(|&c| c > 0),
        "honest workers must be credited through group provenance: {selections:?}"
    );
    assert_eq!(coordinator.metrics.counter("groups_missing"), 0);
    coordinator.shutdown();
}
