//! End-to-end tests for the durable round-journal: warm restart via
//! verified deterministic replay, exactly-once round semantics, and the
//! recovery failure modes (replay divergence, corrupt record, torn
//! tail). The crash-*injection* variant of the same property — a real
//! `--crash-after-round` abort followed by a resumed process — runs in
//! the CI recovery leg (`.github/workflows/ci.yml`); here the
//! interruption is simulated by dropping the coordinator mid-run, which
//! exercises the identical journal state machine without killing the
//! test harness.

use multibulyan::attacks::AttackKind;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::{launch, Coordinator, Journal};
use multibulyan::gar::GarKind;
use multibulyan::transport::TransportKind;
use multibulyan::util;
use std::path::PathBuf;

const TRANSPORTS: [TransportKind; 3] = [
    TransportKind::Threaded,
    TransportKind::Pooled,
    TransportKind::Socket,
];

fn exp(transport: TransportKind, journal: Option<&PathBuf>, seed: u64) -> ExperimentConfig {
    ExperimentConfig {
        cluster: ClusterConfig {
            n: 7,
            f: 1,
            actual_byzantine: Some(1),
            ..Default::default()
        },
        gar: GarKind::MultiKrum,
        pre: Vec::new(),
        attack: AttackKind::SignFlip { scale: 5.0 },
        model: ModelConfig::Quadratic {
            dim: 32,
            noise: 0.3,
        },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.0,
            steps: 6,
            batch_size: 8,
            eval_every: 0,
            seed,
        },
        threads: 2,
        transport,
        collect: Default::default(),
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: journal.map(|p| p.display().to_string()),
        crash_after_round: None,
    }
}

fn journal_path(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!(
        "mb_it_journal_{tag}_{}.mbjr",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&p);
    p
}

/// Run `rounds` view-driven rounds and return the final parameters.
fn drive(coordinator: &mut Coordinator, rounds: usize) -> Vec<f32> {
    for _ in 0..rounds {
        let view = coordinator.next_view();
        coordinator.run_round(&view).unwrap();
    }
    coordinator.params().to_vec()
}

#[test]
fn interrupted_run_resumes_bit_identically_on_every_transport() {
    for transport in TRANSPORTS {
        let path = journal_path(&format!("resume_{transport}"));

        // Reference: 6 uninterrupted rounds, no journal.
        let cluster = launch(&exp(transport, None, 29), None).unwrap();
        let mut coordinator = cluster.coordinator;
        let reference = drive(&mut coordinator, 6);
        coordinator.shutdown();

        // Interrupted run: 3 journalled rounds, then the process "dies"
        // (coordinator dropped without finishing).
        let cluster = launch(&exp(transport, Some(&path), 29), None).unwrap();
        let mut coordinator = cluster.coordinator;
        let at_crash = drive(&mut coordinator, 3);
        assert_eq!(coordinator.metrics.counter("journal_committed"), 3);
        assert_eq!(coordinator.metrics.counter("journal_replayed"), 0);
        coordinator.shutdown();

        // The journal alone carries the restart point.
        let journal = Journal::open(&path).unwrap();
        assert_eq!(journal.last_committed(), 3, "{transport}");
        assert_eq!(journal.truncated_bytes(), 0);
        let rec = journal.record(3).unwrap();
        assert_eq!(rec.round, 3);
        assert_eq!(rec.workers, (0..6u32).collect::<Vec<_>>());
        assert_eq!(rec.collected, 6);
        assert_eq!(rec.missing, 0);
        assert_eq!(
            rec.params_checksum,
            util::fnv1a(at_crash.iter().flat_map(|v| v.to_le_bytes())),
            "{transport}: journalled checksum must match the params at \
             the interruption point"
        );
        drop(journal);

        // Resume: rounds 1..=3 re-execute under verification (replayed,
        // never re-committed — exactly-once), rounds 4..=6 commit.
        let cluster = launch(&exp(transport, Some(&path), 29), None).unwrap();
        let mut coordinator = cluster.coordinator;
        let resumed = drive(&mut coordinator, 6);
        assert_eq!(coordinator.metrics.counter("journal_replayed"), 3);
        assert_eq!(coordinator.metrics.counter("journal_committed"), 3);
        coordinator.shutdown();
        assert_eq!(
            resumed, reference,
            "{transport}: interrupted-then-resumed run must be \
             bit-identical to the uninterrupted run"
        );
        let journal = Journal::open(&path).unwrap();
        assert_eq!(journal.last_committed(), 6);
        assert_eq!(
            journal.expected_checksum(6).unwrap(),
            util::fnv1a(reference.iter().flat_map(|v| v.to_le_bytes()))
        );
        drop(journal);
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn replay_divergence_is_a_hard_error() {
    // A journal from seed 29 resumed under seed 30: round 1 re-executes
    // to different parameters, the checksum verification refuses to
    // continue — a warm restart never silently forks the trajectory.
    let path = journal_path("diverge");
    let cluster = launch(&exp(TransportKind::Pooled, Some(&path), 29), None).unwrap();
    let mut coordinator = cluster.coordinator;
    drive(&mut coordinator, 2);
    coordinator.shutdown();

    let cluster = launch(&exp(TransportKind::Pooled, Some(&path), 30), None).unwrap();
    let mut coordinator = cluster.coordinator;
    let view = coordinator.next_view();
    let err = coordinator.run_round(&view).unwrap_err().to_string();
    assert!(
        err.contains("replay divergence"),
        "wrong error for a diverging replay: {err}"
    );
    coordinator.shutdown();
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_record_refuses_resume_end_to_end() {
    // Flip one payload byte of the first committed record: the frame is
    // complete, so this is corruption — `launch` (via `Journal::open`)
    // must hard-error, not truncate-and-carry-on.
    let path = journal_path("corrupt");
    let cluster = launch(&exp(TransportKind::Pooled, Some(&path), 29), None).unwrap();
    let mut coordinator = cluster.coordinator;
    drive(&mut coordinator, 2);
    coordinator.shutdown();

    let mut bytes = std::fs::read(&path).unwrap();
    bytes[14] ^= 0xFF; // inside record 1's payload (header is 8 bytes, len 4)
    std::fs::write(&path, &bytes).unwrap();

    let err = launch(&exp(TransportKind::Pooled, Some(&path), 29), None)
        .err()
        .expect("corrupt journal must refuse to launch")
        .to_string();
    assert!(err.contains("checksum"), "wrong corrupt-journal error: {err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn torn_tail_is_truncated_and_resume_continues() {
    // A partial frame after the last committed record — the shape a
    // mid-write crash leaves behind — is dropped on open, and the resume
    // still lands on the uninterrupted run's bits.
    let path = journal_path("torn");
    let cluster = launch(&exp(TransportKind::Pooled, None, 29), None).unwrap();
    let mut coordinator = cluster.coordinator;
    let reference = drive(&mut coordinator, 6);
    coordinator.shutdown();

    let cluster = launch(&exp(TransportKind::Pooled, Some(&path), 29), None).unwrap();
    let mut coordinator = cluster.coordinator;
    drive(&mut coordinator, 3);
    coordinator.shutdown();

    // Torn tail: a length field claiming 64 payload bytes, then EOF.
    use std::io::Write;
    let mut file = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
    file.write_all(&64u32.to_le_bytes()).unwrap();
    file.write_all(&[0xAB; 10]).unwrap();
    drop(file);

    let cluster = launch(&exp(TransportKind::Pooled, Some(&path), 29), None).unwrap();
    let mut coordinator = cluster.coordinator;
    let resumed = drive(&mut coordinator, 6);
    assert_eq!(coordinator.metrics.counter("journal_replayed"), 3);
    assert_eq!(coordinator.metrics.counter("journal_committed"), 3);
    coordinator.shutdown();
    assert_eq!(resumed, reference);
    let _ = std::fs::remove_file(&path);
}
