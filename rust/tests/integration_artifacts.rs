//! Integration tests over the AOT artifacts: the three-implementation
//! cross-check (jnp oracle ↔ Pallas/JAX HLO graph ↔ native rust) and the
//! full training loop through PJRT.
//!
//! These tests need `make artifacts` to have run; they self-skip (with a
//! loud message) when `artifacts/manifest.json` is absent so the pure-rust
//! suite stays green in a fresh checkout.

use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::launch;
use multibulyan::gar::GarKind;
use multibulyan::runtime::{ArgValue, ComputeServer, Manifest};
use multibulyan::tensor::GradMatrix;
use multibulyan::util::Rng64;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("SKIP: artifacts/ not built — run `make artifacts` first");
            None
        }
    }
}

/// Native rust GAR vs the AOT-lowered JAX/Pallas GAR graph, on random
/// inputs — the strongest end-to-end correctness signal in the repo.
#[test]
fn native_gar_matches_aot_artifact() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    let server = ComputeServer::start(manifest.clone()).unwrap();
    let handle = server.handle();
    let (n, f, d) = (11usize, 2usize, 1024usize);
    let mut rng = Rng64::seed_from_u64(0xC0DE);
    for (rule, kind) in [
        ("average", GarKind::Average),
        ("median", GarKind::Median),
        ("krum", GarKind::Krum),
        ("multi_krum", GarKind::MultiKrum),
        ("bulyan", GarKind::Bulyan),
        ("multi_bulyan", GarKind::MultiBulyan),
    ] {
        let artifact = format!("gar_{rule}_n{n}_f{f}_d{d}");
        if !manifest.artifacts.contains_key(&artifact) {
            eprintln!("SKIP {artifact}: not in manifest");
            continue;
        }
        for trial in 0..3 {
            let grads = GradMatrix::uniform(n, d, -1.0, 1.0, &mut rng);
            let native = kind
                .instantiate(n, f)
                .unwrap()
                .aggregate(&grads)
                .unwrap();
            let out = handle
                .execute(
                    &artifact,
                    vec![ArgValue::F32(grads.flat().to_vec(), vec![n, d])],
                )
                .unwrap();
            let aot = &out[0];
            assert_eq!(aot.len(), d, "{artifact}");
            let mut max_err = 0.0f32;
            for (a, b) in native.iter().zip(aot) {
                max_err = max_err.max((a - b).abs() / (1.0 + a.abs()));
            }
            assert!(
                max_err < 1e-4,
                "{artifact} trial {trial}: native vs AOT max rel err {max_err}"
            );
        }
        println!("cross-check OK: {artifact}");
    }
}

/// Native SGD+momentum vs the fused Pallas `sgd_d1024` artifact.
#[test]
fn native_sgd_matches_aot_kernel() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    if !manifest.artifacts.contains_key("sgd_d1024") {
        eprintln!("SKIP: sgd_d1024 not in manifest");
        return;
    }
    let server = ComputeServer::start(manifest).unwrap();
    let handle = server.handle();
    let d = 1024usize;
    let mut rng = Rng64::seed_from_u64(7);
    let params: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
    let grad: Vec<f32> = (0..d).map(|_| rng.gaussian()).collect();
    let (lr, mu) = (0.1f32, 0.9f32);

    // Native: two steps.
    let mut native_p = params.clone();
    let mut opt = multibulyan::training::Sgd::new(d, lr, mu).unwrap();
    opt.step(&mut native_p, &grad);
    opt.step(&mut native_p, &grad);

    // Artifact: two steps threading velocity through.
    let mut p = params;
    let mut v = vec![0.0f32; d];
    for _ in 0..2 {
        let out = handle
            .execute(
                "sgd_d1024",
                vec![
                    ArgValue::f32_vec(p.clone()),
                    ArgValue::f32_vec(v.clone()),
                    ArgValue::f32_vec(grad.clone()),
                    ArgValue::F32(vec![lr], vec![1]),
                    ArgValue::F32(vec![mu], vec![1]),
                ],
            )
            .unwrap();
        p = out[0].clone();
        v = out[1].clone();
    }
    for (a, b) in native_p.iter().zip(&p) {
        assert!((a - b).abs() < 1e-5 * (1.0 + a.abs()), "{a} vs {b}");
    }
}

/// Full distributed training through PJRT: the MLP artifact must learn
/// the FashionLike task under MULTI-BULYAN with a live attack.
#[test]
fn training_through_pjrt_learns_under_attack() {
    let Some(manifest) = manifest_or_skip() else {
        return;
    };
    if manifest.model("mlp").is_err() {
        eprintln!("SKIP: mlp model not in manifest");
        return;
    }
    let server = ComputeServer::start(manifest.clone()).unwrap();
    let exp = ExperimentConfig {
        cluster: ClusterConfig {
            n: 11,
            f: 2,
            actual_byzantine: Some(2),
            net_delay_us: 0,
            drop_prob: 0.0,
            round_timeout_ms: 60_000,
            ..Default::default()
        },
        gar: GarKind::MultiBulyan,
        pre: Vec::new(),
        attack: multibulyan::attacks::AttackKind::SignFlip { scale: 1.0 },
        model: ModelConfig::Artifact {
            name: "mlp".into(),
            dir: "artifacts".into(),
        },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.9,
            steps: 25,
            batch_size: 25,
            eval_every: 0,
            seed: 1,
        },
        threads: 1,
        transport: Default::default(),
        collect: Default::default(),
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    };
    let cluster = launch(&exp, Some((server.handle(), manifest))).unwrap();
    let mut coordinator = cluster.coordinator;
    let mut evaluator = cluster.evaluator;
    coordinator.train(25, 0, &mut evaluator).unwrap();
    let acc = coordinator.metrics.max_accuracy();
    coordinator.shutdown();
    assert!(
        acc > 0.5,
        "MLP under multi-bulyan + sign-flip should beat 50% top-1 in 25 steps, got {acc}"
    );
}
