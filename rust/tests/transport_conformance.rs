//! Shared transport conformance suite — every `ServerEndpoint` backend
//! (`threaded`, `pooled`, `socket`) must satisfy the same collection
//! contract, and the socket backend must additionally honor the wire
//! protocol spec in `docs/wire-protocol.md`. Each test names the spec
//! section it enforces (§N references are to that document).
//!
//! The socket-specific tests drive raw frames from the test thread
//! against a server in `external` mode (no in-process clients), so the
//! exact byte sequences of the spec are what crosses the wire.

use multibulyan::codec::{encoder, Codec, CodecKind};
use multibulyan::runtime::Parallelism;
use multibulyan::transport::socket::{
    self, encode, read_frame, write_chunk_frame, write_coded_chunk_frame, write_frame, Frame,
    FrameError, PayloadKind, HEADER_LEN, REJECT_CHECKSUM, REJECT_CODEC, REJECT_DUPLICATE,
    REJECT_MALFORMED, REJECT_VERSION, VERSION,
};
use multibulyan::transport::{
    build, star_socket, ComputeCost, Emitter, FaultModel, ServerEndpoint, SocketOptions,
    TransportKind, WorkerBody,
};
use multibulyan::util;
use std::sync::Arc;
use std::time::Duration;

/// A conformance body: a plain function pointer over (id, round, params,
/// emitter) — trivially `Send`, no closure-inference pitfalls.
struct Body {
    id: usize,
    f: fn(usize, u64, &[f32], &mut Emitter<'_>),
}

impl WorkerBody for Body {
    fn on_round(&mut self, round: u64, params: &[f32], emit: &mut Emitter<'_>) {
        (self.f)(self.id, round, params, emit)
    }
}

/// A body that emits through a gradient codec (`None` = plain send):
/// gradient is `params * 2 + id`, the same shape as [`Body`] scenarios.
struct CodedBody {
    id: usize,
    codec: Option<Box<dyn Codec>>,
}

impl WorkerBody for CodedBody {
    fn on_round(&mut self, round: u64, params: &[f32], emit: &mut Emitter<'_>) {
        let g: Vec<f32> = params.iter().map(|p| p * 2.0 + self.id as f32).collect();
        emit.send_coded(round, &g, self.codec.as_deref_mut());
    }
}

/// A broken encoder: claims fp16 but emits a truncated payload (fp16
/// needs 2 bytes per coordinate), so every server-side decode fails.
struct BadCodec;

impl Codec for BadCodec {
    fn kind(&self) -> CodecKind {
        CodecKind::Fp16
    }

    fn encode(&mut self, _offset: usize, _values: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.push(0xEE);
    }
}

/// Build a star on `kind` and install `f` as every worker's body.
fn harness(
    kind: TransportKind,
    n: usize,
    faults: FaultModel,
    f: fn(usize, u64, &[f32], &mut Emitter<'_>),
) -> ServerEndpoint {
    let (server, workers) = build(kind, n, faults, &Parallelism::new(2));
    for w in workers {
        let id = w.id();
        w.serve(Body { id, f });
    }
    server
}

/// Run the same scenario on all three backends.
fn on_all(test: fn(TransportKind)) {
    for kind in TransportKind::ALL {
        test(kind);
    }
}

// ---------------------------------------------------------------------
// Backend-parameterized contract (threaded, pooled, socket).
// ---------------------------------------------------------------------

#[test]
fn round_trip_delivers_every_worker_on_all_backends() {
    // §6.1 (round lifecycle): broadcast round r, collect n gradients
    // tagged (worker, r), each byte-exact.
    on_all(|kind| {
        let mut server = harness(kind, 4, FaultModel::default(), |id, round, params, emit| {
            let g: Vec<f32> = params.iter().map(|p| p * 2.0 + id as f32).collect();
            emit.send(round, &g);
        });
        server.broadcast(1, Arc::new(vec![0.5, -1.5]));
        let got = server.collect(1, 4, Duration::from_secs(5));
        assert_eq!(got.len(), 4, "{kind}");
        let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3], "{kind}");
        for m in &got {
            assert_eq!(
                m.gradient,
                vec![1.0 + m.worker as f32, -3.0 + m.worker as f32],
                "{kind}"
            );
        }
        server.shutdown();
    });
}

#[test]
fn stale_round_gradients_are_discarded_on_all_backends() {
    // §6.3 (stale-round discard): a gradient tagged with an old round id
    // must never be delivered for the current round, regardless of
    // arrival order relative to the current-round gradient.
    on_all(|kind| {
        let mut server = harness(kind, 1, FaultModel::default(), |_id, _round, _p, emit| {
            emit.send(3, &[9.0]); // stale (current round is 4)
            emit.send(4, &[1.0]);
            emit.send(2, &[8.0]); // stale, after the current round
        });
        server.broadcast(4, Arc::new(vec![0.0]));
        let got = server.collect(4, 1, Duration::from_secs(5));
        assert_eq!(got.len(), 1, "{kind}");
        assert_eq!(got[0].round, 4, "{kind}");
        assert_eq!(got[0].gradient, vec![1.0], "{kind}");
        server.shutdown();
    });
}

#[test]
fn timeout_bounds_first_m_collection_on_all_backends() {
    // §6.2 (deadlines and first-m): a first-m collect proceeds at the
    // fastest m workers, and a wait-all collect with a deadline between
    // the fast tier's cost and the stragglers' leaves exactly the
    // stragglers behind.
    on_all(|kind| {
        let faults = FaultModel {
            cost: ComputeCost {
                base_us: 1_000,
                slow_workers: 2,
                slow_factor: 50.0,
            },
            ..Default::default()
        };
        let mut server = harness(kind, 6, faults, |id, round, _p, emit| {
            emit.send(round, &[id as f32]);
        });
        // First-m: the 4 fast workers fill the quorum.
        server.broadcast(1, Arc::new(vec![0.0]));
        let got = server.collect(1, 4, Duration::from_secs(5));
        let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 3, 4, 5], "{kind}: first-m quorum");
        // Wait-all with a 10 ms deadline: stragglers (50 ms) miss it.
        server.broadcast(2, Arc::new(vec![0.0]));
        let got = server.collect(2, 6, Duration::from_millis(10));
        let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 3, 4, 5], "{kind}: deadline leaves stragglers");
        server.shutdown();
    });
}

#[test]
fn worker_crash_is_isolated_on_all_backends() {
    // §6.4 (crash isolation): one worker dying (body panic; on the
    // socket backend the client thread dies and its connection drops)
    // must not poison the server or the surviving workers — later rounds
    // still collect everyone else.
    on_all(|kind| {
        let mut server = harness(kind, 3, FaultModel::default(), |id, round, _p, emit| {
            if id == 1 {
                panic!("worker 1 crashed");
            }
            emit.send(round, &[id as f32]);
        });
        for round in 1..=2u64 {
            server.broadcast(round, Arc::new(vec![0.0]));
            let got = server.collect(round, 3, Duration::from_millis(300));
            let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 2], "{kind} round {round}");
        }
        server.shutdown();
    });
}

#[test]
fn rejected_gradients_do_not_occupy_quorum_slots_on_all_backends() {
    // §6.2 (quorum accounting) + §5.1 (rejects don't count): a gradient
    // the accept callback refuses must not fill one of the m quorum
    // slots — collection keeps going until m *accepted* gradients.
    on_all(|kind| {
        let mut server = harness(kind, 4, FaultModel::default(), |id, round, _p, emit| {
            emit.send(round, &[id as f32]);
        });
        server.broadcast(1, Arc::new(vec![0.0]));
        let mut accepted = Vec::new();
        let got = server.collect_with(1, 3, Duration::from_secs(5), |worker, gradient| {
            if gradient[0] == 0.0 {
                return false; // reject worker 0's gradient
            }
            accepted.push(worker);
            true
        });
        assert_eq!(got, 3, "{kind}: three accepted despite the reject");
        accepted.sort_unstable();
        assert_eq!(accepted, vec![1, 2, 3], "{kind}");
        server.shutdown();
    });
}

#[test]
fn lossless_coded_gradients_cross_every_backend_bit_identical() {
    // §7 (codec integration): a worker encoding with the lossless codec
    // must deliver byte-exact gradients on every backend — threaded and
    // pooled decode at server-side delivery, the socket backend decodes
    // negotiated coded chunks at reassembly.
    for kind in TransportKind::ALL {
        let (mut server, workers) = match kind {
            TransportKind::Socket => star_socket(
                3,
                FaultModel::default(),
                &SocketOptions {
                    listen: None,
                    chunk: socket::DEFAULT_CHUNK,
                    external: false,
                    codec: CodecKind::Lossless,
                },
            )
            .expect("loopback bind"),
            _ => build(kind, 3, FaultModel::default(), &Parallelism::new(2)),
        };
        for w in workers {
            let id = w.id();
            w.serve(CodedBody {
                id,
                codec: Some(encoder(CodecKind::Lossless)),
            });
        }
        server.broadcast(1, Arc::new(vec![0.5, -1.5, 3.25]));
        let got = server.collect(1, 3, Duration::from_secs(5));
        assert_eq!(got.len(), 3, "{kind}");
        for m in &got {
            let id = m.worker as f32;
            assert_eq!(m.gradient, vec![1.0 + id, -3.0 + id, 6.5 + id], "{kind}");
        }
        server.shutdown();
    }
}

#[test]
fn undecodable_coded_gradient_never_occupies_a_quorum_slot_on_any_backend() {
    // §7 + §6.2 (quorum accounting): worker 0's encoder emits garbage —
    // threaded/pooled reject it at server-side decode, the socket
    // backend rejects the mistagged chunk against its raw-negotiated
    // connection — and a first-m collect of 3 out of 4 is still filled
    // by the three honest workers; the bad payload takes no slot.
    for kind in TransportKind::ALL {
        let (mut server, workers) = build(kind, 4, FaultModel::default(), &Parallelism::new(2));
        for w in workers {
            let id = w.id();
            let codec: Option<Box<dyn Codec>> =
                if id == 0 { Some(Box::new(BadCodec)) } else { None };
            w.serve(CodedBody { id, codec });
        }
        server.broadcast(1, Arc::new(vec![2.0]));
        let got = server.collect(1, 3, Duration::from_secs(5));
        let mut ids: Vec<usize> = got.iter().map(|m| m.worker).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2, 3], "{kind}");
        server.shutdown();
    }
}

// ---------------------------------------------------------------------
// Socket-specific: raw frames against an external-mode server.
// ---------------------------------------------------------------------

/// Bind an external-mode loopback server for `n` workers (no in-process
/// clients — the test owns every byte on the wire).
fn external_server(n: usize, chunk: usize) -> ServerEndpoint {
    let opts = SocketOptions {
        listen: None,
        chunk,
        external: true,
        codec: CodecKind::Raw,
    };
    let (server, _slots) = star_socket(n, FaultModel::default(), &opts).expect("loopback bind");
    server
}

/// Raw client handshake (§6.5): connect, send Hello, read the ack.
fn raw_register(addr: &str, worker: u32) -> socket::Stream {
    let mut conn = socket::connect_stream(addr).expect("connect");
    write_frame(
        &mut conn,
        &Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker,
            payload: Vec::new(),
        },
    )
    .expect("hello");
    let ack = read_frame(&mut conn, None).expect("hello ack");
    assert_eq!(ack.kind, PayloadKind::Hello);
    assert_eq!(ack.worker, worker);
    conn
}

#[test]
fn corrupted_checksum_is_rejected_and_the_connection_survives() {
    // §5.1 (checksum failure): a frame whose payload checksum does not
    // match draws a Reject(CHECKSUM), never reaches the collect session
    // (no quorum slot), and the connection stays usable.
    let mut server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().expect("socket backend").to_string();
    let mut conn = raw_register(&addr, 0);

    server.broadcast(1, Arc::new(vec![0.5f32; 3]));
    let rr = read_frame(&mut conn, None).expect("round result");
    assert_eq!(rr.kind, PayloadKind::RoundResult);
    assert_eq!(rr.round, 1);
    assert_eq!(socket::parse_params(&rr.payload).unwrap(), vec![0.5f32; 3]);

    // A well-formed gradient frame with one payload byte flipped after
    // the checksum was computed.
    let mut scratch = Vec::new();
    let mut probe = Vec::new();
    write_chunk_frame(&mut probe, 0, 1, 0, 3, &[7.0, 7.0, 7.0], &mut scratch).unwrap();
    probe[HEADER_LEN + 8] ^= 0xFF; // corrupt a gradient byte
    use std::io::Write;
    conn.write_all(&probe).unwrap();

    let reject = read_frame(&mut conn, None).expect("reject frame");
    assert_eq!(reject.kind, PayloadKind::Reject);
    assert_eq!(reject.payload, vec![REJECT_CHECKSUM]);

    // Same connection, now a valid gradient: it must be the one and only
    // delivery — the corrupted frame occupied no slot.
    write_chunk_frame(&mut conn, 0, 1, 0, 3, &[1.0, 2.0, 3.0], &mut scratch).unwrap();
    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].worker, 0);
    assert_eq!(got[0].gradient, vec![1.0, 2.0, 3.0]);
    server.shutdown();
}

#[test]
fn version_mismatch_draws_reject_version_and_a_close() {
    // §5.2 (version negotiation): a Hello with an unknown protocol
    // version is answered with Reject(VERSION) and the connection is
    // closed — no silent downgrade.
    let server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    let mut conn = socket::connect_stream(&addr).expect("connect");
    let mut hello = encode(&Frame {
        kind: PayloadKind::Hello,
        round: 0,
        worker: 0,
        payload: Vec::new(),
    });
    hello[4..6].copy_from_slice(&(VERSION + 1).to_le_bytes());
    use std::io::Write;
    conn.write_all(&hello).unwrap();

    let reject = read_frame(&mut conn, None).expect("reject frame");
    assert_eq!(reject.kind, PayloadKind::Reject);
    assert_eq!(reject.payload, vec![REJECT_VERSION]);
    assert_eq!(reject.worker, u32::MAX, "no worker registered yet");
    assert!(
        matches!(read_frame(&mut conn, None), Err(FrameError::Closed)),
        "connection must be closed after a version reject"
    );
    server.shutdown();
}

#[test]
fn malformed_and_short_frames_never_occupy_a_quorum_slot() {
    // §5.3 (fatal framing errors) + §6.2 (quorum accounting): a
    // bad-magic connection and a mid-header hangup are both dropped
    // without registering anything; a healthy worker on a fresh
    // connection still fills the quorum alone.
    let mut server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    use std::io::Write;

    // Bad magic: a full-length garbage header.
    let mut bad = socket::connect_stream(&addr).expect("connect");
    bad.write_all(&[0xAAu8; HEADER_LEN]).unwrap();
    // Short frame: a truncated header, then hangup (drop closes it).
    let mut short = socket::connect_stream(&addr).expect("connect");
    short.write_all(&[0x4D, 0x42, 0x57, 0x50, 0x01]).unwrap();
    drop(short);

    // The healthy client registers and delivers; expect = 1 must be
    // filled by it, proving neither bad stream consumed the slot.
    let mut conn = raw_register(&addr, 0);
    server.broadcast(1, Arc::new(vec![0.0f32]));
    let rr = read_frame(&mut conn, None).expect("round result");
    assert_eq!(rr.round, 1);
    let mut scratch = Vec::new();
    write_chunk_frame(&mut conn, 0, 1, 0, 1, &[5.0], &mut scratch).unwrap();
    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].gradient, vec![5.0]);
    server.shutdown();
}

#[test]
fn duplicate_worker_registration_live_incumbent_wins() {
    // §6.5 (registration state machine, v3): a plain second Hello
    // claiming an occupied worker id probes the incumbent with a Hello
    // ping; a live incumbent wins — the newcomer draws Reject(DUPLICATE)
    // and a close, and the incumbent (after reading the informational
    // ping) keeps the slot and keeps working.
    let mut server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    let mut first = raw_register(&addr, 0);

    let mut imposter = socket::connect_stream(&addr).expect("connect");
    write_frame(
        &mut imposter,
        &Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker: 0,
            payload: Vec::new(),
        },
    )
    .unwrap();
    let reject = read_frame(&mut imposter, None).expect("reject frame");
    assert_eq!(reject.kind, PayloadKind::Reject);
    assert_eq!(reject.payload, vec![REJECT_DUPLICATE]);
    assert!(matches!(
        read_frame(&mut imposter, None),
        Err(FrameError::Closed)
    ));

    // The incumbent received the liveness probe — an informational Hello
    // ping clients must tolerate (§8.2).
    let ping = read_frame(&mut first, None).expect("liveness probe");
    assert_eq!(ping.kind, PayloadKind::Hello);
    assert_eq!(ping.worker, 0);

    server.broadcast(1, Arc::new(vec![0.25f32]));
    let rr = read_frame(&mut first, None).expect("round result");
    assert_eq!(rr.round, 1);
    let mut scratch = Vec::new();
    write_chunk_frame(&mut first, 0, 1, 0, 1, &[4.0], &mut scratch).unwrap();
    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].gradient, vec![4.0]);
    server.shutdown();
}

/// Raw v3 handshake with a flags byte: payload `[codec, flags]` (§8.2).
fn raw_register_flags(addr: &str, worker: u32, flags: u8) -> socket::Stream {
    let mut conn = socket::connect_stream(addr).expect("connect");
    write_frame(
        &mut conn,
        &Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker,
            payload: vec![CodecKind::Raw.wire_id(), flags],
        },
    )
    .expect("hello");
    let ack = read_frame(&mut conn, None).expect("hello ack");
    assert_eq!(ack.kind, PayloadKind::Hello);
    assert_eq!(ack.worker, worker);
    conn
}

#[test]
fn rejoin_hello_evicts_the_incumbent_deterministically() {
    // §8.2 (rejoin): a Hello whose flags byte sets bit 0 claims the slot
    // unconditionally — the incumbent connection is shut down without a
    // liveness probe (the operator asserted the restart) and the new
    // connection carries the id from then on. This is the fix for the
    // crashed-and-restarted external worker whose dead connection the
    // server has not yet reaped: first-connection-wins would turn the
    // restarted process away forever.
    let mut server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    let mut first = raw_register(&addr, 0);

    let mut second = raw_register_flags(&addr, 0, 0x01);

    // The evicted incumbent's connection is closed by the server.
    assert!(
        matches!(read_frame(&mut first, None), Err(FrameError::Closed)),
        "evicted incumbent must observe a close"
    );

    // The new connection owns the slot: it gets the round and its
    // gradient is the delivery.
    server.broadcast(1, Arc::new(vec![0.0f32]));
    let rr = read_frame(&mut second, None).expect("round result");
    assert_eq!(rr.kind, PayloadKind::RoundResult);
    assert_eq!(rr.round, 1);
    let mut scratch = Vec::new();
    write_chunk_frame(&mut second, 0, 1, 0, 1, &[6.0], &mut scratch).unwrap();
    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].worker, 0);
    assert_eq!(got[0].gradient, vec![6.0]);
    assert_eq!(
        server.departed_workers(),
        Vec::<usize>::new(),
        "an evicted-and-replaced id is present, not departed"
    );
    server.shutdown();
}

#[test]
fn reserved_hello_flag_bits_draw_reject_malformed() {
    // §8.2: the flags byte has exactly one defined bit; a Hello setting
    // any reserved bit is malformed — no silent ignore that would make
    // future flag assignments ambiguous.
    let server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    let mut conn = socket::connect_stream(&addr).expect("connect");
    write_frame(
        &mut conn,
        &Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker: 0,
            payload: vec![CodecKind::Raw.wire_id(), 0x02],
        },
    )
    .unwrap();
    let reject = read_frame(&mut conn, None).expect("reject frame");
    assert_eq!(reject.kind, PayloadKind::Reject);
    assert_eq!(reject.payload, vec![REJECT_MALFORMED]);
    assert!(matches!(read_frame(&mut conn, None), Err(FrameError::Closed)));
    server.shutdown();
}

#[test]
fn crashed_worker_rejoins_with_a_plain_hello() {
    // §6.4 + §8.1/§8.2: an abrupt disconnect (process death) marks the
    // id departed, and once the server has reaped the EOF a restarted
    // worker re-registers with a plain Hello — no rejoin flag needed.
    // (In the un-reaped window the restart would instead win the §6.5
    // probe arbitration or force the slot with the rejoin flag; those
    // branches are pinned by the two tests above.)
    let mut server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    let first = raw_register(&addr, 0);
    drop(first); // crash: no Goodbye, no Shutdown — just EOF

    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        if server.departed_workers() == vec![0] {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "crash-detected disconnect never surfaced in departed_workers()"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    let mut back = raw_register(&addr, 0);
    assert_eq!(server.departed_workers(), Vec::<usize>::new());
    server.broadcast(1, Arc::new(vec![0.0f32]));
    let rr = read_frame(&mut back, None).expect("round result");
    assert_eq!(rr.round, 1);
    let mut scratch = Vec::new();
    write_chunk_frame(&mut back, 0, 1, 0, 1, &[5.0], &mut scratch).unwrap();
    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].gradient, vec![5.0]);
    server.shutdown();
}

#[test]
fn goodbye_marks_departure_and_frees_the_slot_for_rejoin() {
    // §8.1 (orderly departure): a Goodbye frame deregisters the sender —
    // the id shows up in `departed_workers()` so the coordinator can
    // shrink the next membership view — and the slot is free for a later
    // Hello, which clears the departure flag again.
    let mut server = external_server(2, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    let mut w0 = raw_register(&addr, 0);
    let _w1 = raw_register(&addr, 1);

    write_frame(
        &mut w0,
        &Frame {
            kind: PayloadKind::Goodbye,
            round: 0,
            worker: 0,
            payload: Vec::new(),
        },
    )
    .unwrap();
    // The reader thread processes the Goodbye asynchronously.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        if server.departed_workers() == vec![0] {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "Goodbye never surfaced in departed_workers()"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // Rejoin on a fresh connection: the departure flag clears and the
    // worker delivers again.
    let mut back = raw_register(&addr, 0);
    assert_eq!(server.departed_workers(), Vec::<usize>::new());
    server.broadcast(1, Arc::new(vec![0.0f32]));
    let rr = read_frame(&mut back, None).expect("round result");
    assert_eq!(rr.round, 1);
    let mut scratch = Vec::new();
    write_chunk_frame(&mut back, 0, 1, 0, 1, &[3.0], &mut scratch).unwrap();
    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].worker, 0);
    server.shutdown();
}

#[test]
fn out_of_order_chunks_are_rejected_then_reassembly_recovers() {
    // §4.3 (GradientChunk ordering): chunks must start at offset 0 and
    // arrive strictly in order; a violation draws Reject(MALFORMED) and
    // resets the assembly, after which a correct in-order gradient on
    // the same connection is delivered whole.
    let mut server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    let mut conn = raw_register(&addr, 0);
    server.broadcast(1, Arc::new(vec![0.0f32; 4]));
    let _ = read_frame(&mut conn, None).expect("round result");

    let mut scratch = Vec::new();
    // Offset 2 with no offset-0 predecessor: out of order.
    write_chunk_frame(&mut conn, 0, 1, 2, 4, &[9.0, 9.0], &mut scratch).unwrap();
    let reject = read_frame(&mut conn, None).expect("reject frame");
    assert_eq!(reject.kind, PayloadKind::Reject);
    assert_eq!(reject.payload, vec![REJECT_MALFORMED]);

    // Correct two-chunk gradient: offsets 0 then 2, totals matching.
    write_chunk_frame(&mut conn, 0, 1, 0, 4, &[1.0, 2.0], &mut scratch).unwrap();
    write_chunk_frame(&mut conn, 0, 1, 2, 4, &[3.0, 4.0], &mut scratch).unwrap();
    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].gradient, vec![1.0, 2.0, 3.0, 4.0]);
    server.shutdown();
}

/// Raw client handshake advertising a codec capability byte (§7).
fn raw_register_coded(addr: &str, worker: u32, codec: CodecKind) -> socket::Stream {
    let mut conn = socket::connect_stream(addr).expect("connect");
    write_frame(
        &mut conn,
        &Frame {
            kind: PayloadKind::Hello,
            round: 0,
            worker,
            payload: vec![codec.wire_id()],
        },
    )
    .expect("hello");
    let ack = read_frame(&mut conn, None).expect("hello ack");
    assert_eq!(ack.kind, PayloadKind::Hello);
    assert_eq!(ack.worker, worker);
    conn
}

#[test]
fn unknown_hello_codec_capability_draws_reject_codec_and_a_close() {
    // §7 (codec negotiation): a Hello advertising an unknown codec id —
    // or an overlong capability payload — is answered with Reject(CODEC)
    // and the connection is closed; no silent fallback to raw.
    let server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    for payload in [vec![200u8], vec![0u8, 0u8]] {
        let mut conn = socket::connect_stream(&addr).expect("connect");
        write_frame(
            &mut conn,
            &Frame {
                kind: PayloadKind::Hello,
                round: 0,
                worker: 0,
                payload,
            },
        )
        .unwrap();
        let reject = read_frame(&mut conn, None).expect("reject frame");
        assert_eq!(reject.kind, PayloadKind::Reject);
        assert_eq!(reject.payload, vec![REJECT_CODEC]);
        assert!(
            matches!(read_frame(&mut conn, None), Err(FrameError::Closed)),
            "connection must be closed after a capability reject"
        );
    }
    server.shutdown();
}

#[test]
fn undecodable_coded_chunk_draws_reject_codec_then_recovery() {
    // §7 (coded chunks): an encoded payload that fails decode draws
    // Reject(CODEC), occupies no quorum slot (§6.2), and the connection
    // stays usable — a valid coded gradient on the same connection is
    // the one and only delivery.
    let mut server = external_server(1, socket::DEFAULT_CHUNK);
    let addr = server.socket_addr().unwrap().to_string();
    let mut conn = raw_register_coded(&addr, 0, CodecKind::Fp16);

    server.broadcast(1, Arc::new(vec![0.0f32; 3]));
    let rr = read_frame(&mut conn, None).expect("round result");
    assert_eq!(rr.kind, PayloadKind::RoundResult);

    let mut scratch = Vec::new();
    // Truncated fp16 payload: 3 coordinates need 6 bytes, not 1.
    write_coded_chunk_frame(
        &mut conn,
        0,
        1,
        0,
        3,
        3,
        CodecKind::Fp16.wire_id(),
        &[0xEE],
        &mut scratch,
    )
    .unwrap();
    let reject = read_frame(&mut conn, None).expect("reject frame");
    assert_eq!(reject.kind, PayloadKind::Reject);
    assert_eq!(reject.payload, vec![REJECT_CODEC]);

    // Same connection, a valid fp16 gradient (values exactly
    // representable in fp16, so the decode is bit-exact).
    let mut enc = Vec::new();
    encoder(CodecKind::Fp16).encode(0, &[1.0, -2.5, 0.75], &mut enc);
    write_coded_chunk_frame(
        &mut conn,
        0,
        1,
        0,
        3,
        3,
        CodecKind::Fp16.wire_id(),
        &enc,
        &mut scratch,
    )
    .unwrap();
    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].worker, 0);
    assert_eq!(got[0].gradient, vec![1.0, -2.5, 0.75]);
    server.shutdown();
}

#[test]
fn streamed_chunks_reassemble_bit_identical_to_one_shot() {
    // §4.3 (chunk-wise streaming): GradWorker::stream_round over a small
    // chunk size, sent frame by frame over the wire, must reassemble to
    // the exact gradient the one-shot path computes.
    use multibulyan::data::QuadraticProblem;
    use multibulyan::worker::{GradSource, GradWorker};

    let problem = Arc::new(QuadraticProblem::new(11, 0.2, 7));
    let one_shot = {
        let mut src = GradSource::quadratic(Arc::clone(&problem), 0, 4);
        src.gradient(&vec![0.1f32; 11], 1).unwrap().0
    };

    let mut server = external_server(1, 3);
    let addr = server.socket_addr().unwrap().to_string();
    let mut conn = raw_register(&addr, 0);
    server.broadcast(1, Arc::new(vec![0.1f32; 11]));
    let rr = read_frame(&mut conn, None).expect("round result");
    let params = socket::parse_params(&rr.payload).unwrap();

    let mut w = GradWorker::new(GradSource::quadratic(Arc::clone(&problem), 0, 4));
    let mut scratch = Vec::new();
    let mut frames = 0usize;
    w.stream_round(1, &params, 3, &mut |offset, values, total| {
        frames += 1;
        write_chunk_frame(
            &mut conn,
            0,
            1,
            offset as u32,
            total as u32,
            values,
            &mut scratch,
        )
        .is_ok()
    })
    .unwrap();
    assert_eq!(frames, 4, "11 coordinates in 3-coordinate chunks");

    let got = server.collect(1, 1, Duration::from_secs(5));
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].gradient, one_shot, "bit-identical to one-shot");
    server.shutdown();
}

#[cfg(unix)]
#[test]
fn unix_domain_socket_round_trip() {
    // §1 (address forms): `unix:PATH` binds a Unix domain socket; the
    // full broadcast/collect round lifecycle (§6.1) runs over it with
    // in-process clients.
    let path = std::env::temp_dir().join(format!("mb-conformance-{}.sock", std::process::id()));
    let opts = SocketOptions {
        listen: Some(format!("unix:{}", path.display())),
        chunk: 4,
        external: false,
        codec: CodecKind::Raw,
    };
    fn body(id: usize, round: u64, params: &[f32], emit: &mut Emitter<'_>) {
        let g: Vec<f32> = params.iter().map(|p| p + id as f32).collect();
        emit.send(round, &g);
    }
    let (mut server, workers) =
        star_socket(2, FaultModel::default(), &opts).expect("uds bind");
    for w in workers {
        let id = w.id();
        w.serve(Body { id, f: body });
    }
    server.broadcast(1, Arc::new(vec![1.0; 6]));
    let got = server.collect(1, 2, Duration::from_secs(5));
    assert_eq!(got.len(), 2);
    for m in &got {
        assert_eq!(m.gradient, vec![1.0 + m.worker as f32; 6]);
    }
    server.shutdown();
    assert!(!path.exists(), "socket file unlinked at shutdown");
}

// ---------------------------------------------------------------------
// Invariant catalog: frame-codec determinism (§3).
// ---------------------------------------------------------------------

#[test]
fn frame_codec_encode_decode_is_bit_identical_property() {
    // §3 (codec invariants): for random frames, decode(encode(f)) == f
    // and encode(decode(bytes)) == bytes — the codec is a bijection on
    // well-formed frames, so checksums and determinism diffs are
    // meaningful across processes and architectures.
    let kinds = [
        PayloadKind::Hello,
        PayloadKind::RoundResult,
        PayloadKind::GradientChunk,
        PayloadKind::Reject,
        PayloadKind::Shutdown,
        PayloadKind::Goodbye,
    ];
    util::proptest::check(
        "frame codec bit-identity",
        util::proptest::default_cases(),
        |rng, _case| {
            let kind = kinds[rng.gen_range_usize(kinds.len())];
            let len = rng.gen_range_usize(257);
            let payload: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let frame = Frame {
                kind,
                round: rng.next_u64(),
                worker: rng.next_u64() as u32,
                payload,
            };
            let bytes = encode(&frame);
            let mut cursor = std::io::Cursor::new(bytes.clone());
            let back = read_frame(&mut cursor, None).map_err(|e| format!("decode: {e:?}"))?;
            if back != frame {
                return Err(format!("decode(encode(f)) != f for {frame:?}"));
            }
            if encode(&back) != bytes {
                return Err("encode(decode(bytes)) != bytes".to_string());
            }
            Ok(())
        },
    );
}
