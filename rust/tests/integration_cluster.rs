//! Cluster-level integration tests on the rust-native workload: every
//! GAR × every attack round-trips through the full coordinator/transport/
//! worker stack, fault injection works, and the headline resilience
//! claims hold end-to-end.

use multibulyan::attacks::AttackKind;
use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::launch;
use multibulyan::gar::GarKind;

fn quadratic_exp(
    gar: GarKind,
    attack: AttackKind,
    n: usize,
    f: usize,
    steps: usize,
) -> ExperimentConfig {
    ExperimentConfig {
        cluster: ClusterConfig {
            n,
            f: if gar == GarKind::Average { 0 } else { f },
            actual_byzantine: Some(if attack == AttackKind::None { 0 } else { f }),
            net_delay_us: 0,
            drop_prob: 0.0,
            round_timeout_ms: 60_000,
            ..Default::default()
        },
        gar,
        pre: Vec::new(),
        attack,
        model: ModelConfig::Quadratic {
            dim: 128,
            noise: 0.3,
        },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.0,
            steps,
            batch_size: 8,
            eval_every: 0,
            seed: 5,
        },
        threads: 1,
        transport: Default::default(),
        collect: Default::default(),
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    }
}

fn final_loss(exp: &ExperimentConfig) -> f32 {
    let cluster = launch(exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    let mut evaluator = cluster.evaluator;
    coordinator
        .train(exp.train.steps, 0, &mut evaluator)
        .unwrap();
    let loss = coordinator.metrics.final_loss().unwrap();
    coordinator.shutdown();
    loss
}

#[test]
fn every_gar_converges_without_attack() {
    for kind in GarKind::ALL {
        let exp = quadratic_exp(kind, AttackKind::None, 11, 2, 250);
        let loss = final_loss(&exp);
        assert!(loss < 5e-3, "{kind}: clean final loss {loss}");
    }
}

#[test]
fn resilient_gars_survive_every_attack() {
    for kind in [
        GarKind::Krum,
        GarKind::MultiKrum,
        GarKind::Median,
        GarKind::TrimmedMean,
        GarKind::Bulyan,
        GarKind::MultiBulyan,
    ] {
        for attack in AttackKind::gauntlet() {
            let exp = quadratic_exp(kind, attack, 11, 2, 250);
            let loss = final_loss(&exp);
            assert!(
                loss.is_finite() && loss < 0.05,
                "{kind} under {}: final loss {loss}",
                attack.label()
            );
        }
    }
}

#[test]
fn averaging_breaks_under_value_attacks() {
    for attack in [
        AttackKind::SignFlip { scale: 10.0 },
        AttackKind::Infinity { nan: false },
        AttackKind::RandomGauss { scale: 100.0 },
    ] {
        let exp = quadratic_exp(GarKind::Average, attack, 11, 2, 100);
        let loss = final_loss(&exp);
        assert!(
            !loss.is_finite() || loss > 0.05,
            "averaging unexpectedly survived {}: {loss}",
            attack.label()
        );
    }
}

#[test]
fn training_tolerates_network_faults() {
    // 10% drop probability: rounds proceed via the last-known-gradient
    // fallback and training still converges.
    let mut exp = quadratic_exp(GarKind::MultiKrum, AttackKind::None, 7, 1, 300);
    exp.cluster.drop_prob = 0.10;
    exp.cluster.net_delay_us = 20;
    // Short straggler timeout: a dropped gradient must cost ~ms, not the
    // default 60 s production timeout.
    exp.cluster.round_timeout_ms = 20;
    let cluster = launch(&exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    let mut evaluator = cluster.evaluator;
    coordinator.train(300, 0, &mut evaluator).unwrap();
    let loss = coordinator.metrics.final_loss().unwrap();
    let missing = coordinator.metrics.counter("gradients_missing");
    coordinator.shutdown();
    assert!(missing > 0, "fault injection produced no missing gradients");
    assert!(loss < 5e-3, "faulty-network final loss {loss}");
}

#[test]
fn over_contract_byzantines_break_weak_rules() {
    // Violating the contract (actual byzantine > f declared) must be
    // able to break even resilient rules — the (n, f) contract of
    // §II-C-c is meaningful.
    let mut exp = quadratic_exp(
        GarKind::Krum,
        AttackKind::LittleIsEnough { z: Some(2.0) },
        11,
        2,
        150,
    );
    exp.cluster.actual_byzantine = Some(6); // majority coalition
    let loss = final_loss(&exp);
    assert!(
        loss > 1e-3,
        "krum with a majority coalition should not fully converge: {loss}"
    );
}

#[test]
fn seeded_runs_are_reproducible() {
    let exp = quadratic_exp(GarKind::MultiBulyan, AttackKind::LittleIsEnough { z: None }, 11, 2, 40);
    let a = final_loss(&exp);
    let b = final_loss(&exp);
    assert_eq!(a, b, "same seed must give bit-identical runs");
    let mut exp2 = exp.clone();
    exp2.train.seed = 6;
    let c = final_loss(&exp2);
    assert_ne!(a, c, "different seeds should differ");
}

#[test]
fn config_file_round_trip_drives_training() {
    let dir = std::env::temp_dir().join("mb_cluster_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
        gar = "multi-bulyan"
        attack = "sign-flip"
        [cluster]
        n = 11
        f = 2
        [model]
        kind = "quadratic"
        dim = 64
        noise = 0.2
        [train]
        steps = 60
        batch_size = 8
        momentum = 0.0
        learning_rate = 0.1
        eval_every = 0
        seed = 2
        "#,
    )
    .unwrap();
    let exp = ExperimentConfig::from_path(&path).unwrap();
    let loss = final_loss(&exp);
    assert!(loss < 0.05, "config-driven run final loss {loss}");
    std::fs::remove_dir_all(dir).ok();
}
