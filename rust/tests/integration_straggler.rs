//! Straggler-race integration tests for deadline-aware first-m
//! collection: a seeded run with a deterministic per-worker compute-cost
//! model must collect the same gradients — and land on bit-identical
//! parameters — on the time-sliced pooled backend (virtual-time races)
//! and the threaded backend (real wall-clock races), at every thread
//! count; stragglers left behind by first-m are recovered through the
//! last-good cache.

use multibulyan::config::{ClusterConfig, ExperimentConfig, ModelConfig, TrainConfig};
use multibulyan::coordinator::{launch, Coordinator, CoordinatorOptions, Evaluator};
use multibulyan::data::QuadraticProblem;
use multibulyan::gar::GarKind;
use multibulyan::runtime::Parallelism;
use multibulyan::transport::{
    build, CollectMode, Emitter, FaultModel, TransportKind, WorkerBody,
};
use multibulyan::worker::{GradSource, GradWorker};
use std::sync::Arc;
use std::time::Duration;

fn straggler_exp(
    n: usize,
    f: usize,
    stragglers: usize,
    collect: CollectMode,
    transport: TransportKind,
    threads: usize,
) -> ExperimentConfig {
    ExperimentConfig {
        cluster: ClusterConfig {
            n,
            f,
            actual_byzantine: Some(0),
            round_timeout_ms: 60_000,
            // Decisive cost gap: the slow tier is 25× the fast tier, so
            // the first-m race's outcome is deterministic on both
            // backends (virtual time on pooled, real sleeps on threaded).
            compute_cost_us: 300,
            stragglers,
            straggler_factor: 25.0,
            ..Default::default()
        },
        gar: GarKind::MultiKrum,
        pre: Vec::new(),
        attack: multibulyan::attacks::AttackKind::None,
        model: ModelConfig::Quadratic {
            dim: 512,
            noise: 0.3,
        },
        train: TrainConfig {
            learning_rate: 0.1,
            momentum: 0.0,
            steps: 6,
            batch_size: 8,
            eval_every: 0,
            seed: 11,
        },
        threads,
        transport,
        collect,
        overlap: Default::default(),
        overlap_window: 1,
        codec: None,
        groups: 1,
        output_dir: None,
        journal: None,
        crash_after_round: None,
    }
}

/// Run 6 first-m rounds; return the final parameters and the per-round
/// (collected, missing) outcome counts.
fn run_first_m(transport: TransportKind, threads: usize) -> (Vec<f32>, Vec<(usize, usize)>) {
    // n = 16, f = 3, stragglers = 3 ⇒ the fast tier is exactly the
    // first-m quorum m = 13: the collected set is cost-determined, not
    // scheduling-determined, on both backends.
    let exp = straggler_exp(16, 3, 3, CollectMode::FirstM, transport, threads);
    let cluster = launch(&exp, None).unwrap();
    let mut coordinator = cluster.coordinator;
    let mut outcomes = Vec::new();
    for _ in 0..6 {
        let view = coordinator.next_view();
        let out = coordinator.run_round(&view).unwrap();
        outcomes.push((out.collected, out.missing));
    }
    let params = coordinator.params().to_vec();
    coordinator.shutdown();
    (params, outcomes)
}

#[test]
fn first_m_runs_are_bit_identical_across_backends_and_thread_counts() {
    let (ref_params, ref_outcomes) = run_first_m(TransportKind::Threaded, 1);
    // Every round: the fastest m = 13 collected, the 3 stragglers cached.
    assert!(ref_outcomes.iter().all(|&(c, m)| c == 13 && m == 3));
    for threads in [1usize, 2, 4] {
        let (params, outcomes) = run_first_m(TransportKind::Pooled, threads);
        assert_eq!(
            ref_outcomes, outcomes,
            "pooled threads={threads}: RoundOutcome collected/missing diverged"
        );
        assert_eq!(
            ref_params, params,
            "pooled threads={threads}: first-m params diverged from threaded"
        );
    }
    let (params, outcomes) = run_first_m(TransportKind::Threaded, 2);
    assert_eq!(ref_outcomes, outcomes);
    assert_eq!(ref_params, params, "threaded threads=2 diverged");
}

#[test]
fn wait_all_with_cost_model_is_bit_identical_across_backends() {
    // Under `all` the stragglers finish within the timeout on both
    // backends, so this exercises the chunked (StepBody) gradient
    // computation end to end: the pooled stragglers compute their
    // gradients a few coordinates per slice and must still emit exactly
    // what the threaded one-shot computation emits.
    let run = |transport: TransportKind, threads: usize| -> Vec<f32> {
        let exp = straggler_exp(12, 2, 2, CollectMode::All, transport, threads);
        let cluster = launch(&exp, None).unwrap();
        let mut coordinator = cluster.coordinator;
        for _ in 0..4 {
            let view = coordinator.next_view();
            let out = coordinator.run_round(&view).unwrap();
            assert_eq!(out.collected, 12, "wait-all must get everyone");
            assert_eq!(out.missing, 0);
        }
        let params = coordinator.params().to_vec();
        coordinator.shutdown();
        params
    };
    let reference = run(TransportKind::Threaded, 1);
    assert_eq!(reference, run(TransportKind::Pooled, 1));
    assert_eq!(reference, run(TransportKind::Pooled, 4));
}

/// Delivers normally in round 1, then goes silent for good — the
/// deterministic stand-in for a worker that straggles past every later
/// deadline (no sleeps, no races).
struct WarmupThenSilent(GradWorker);
impl WorkerBody for WarmupThenSilent {
    fn on_round(&mut self, round: u64, params: &[f32], emit: &mut Emitter<'_>) {
        if round == 1 {
            self.0.on_round(round, params, emit);
        }
    }
}

#[test]
fn straggler_is_left_behind_by_the_deadline_and_recovered_from_the_last_good_cache() {
    // Collection semantics are a construction-time knob now (the post-hoc
    // `set_collect` mutator no longer exists), so the cache warm-up is
    // scripted at the worker instead: worker 7 delivers once in round 1
    // and never again. Wait-all collects everyone in round 1 (populating
    // the cache), and every later round times out at 7 gradients and
    // substitutes the cached round-1 gradient — training stays healthy.
    let d = 32;
    let problem = Arc::new(QuadraticProblem::new(d, 0.1, 11));
    let (server, workers) = build(
        TransportKind::Threaded,
        8,
        FaultModel::default(),
        &Parallelism::new(1),
    );
    for (i, ep) in workers.into_iter().enumerate() {
        let inner = GradWorker::new(GradSource::quadratic(Arc::clone(&problem), i, 8));
        if i == 7 {
            ep.serve(WarmupThenSilent(inner));
        } else {
            ep.serve(inner);
        }
    }
    let mut coordinator = Coordinator::builder(GarKind::MultiKrum.instantiate(8, 1).unwrap())
        .options(CoordinatorOptions {
            round_timeout: Duration::from_millis(40),
            collect: CollectMode::All,
            ..Default::default()
        })
        .build(server, vec![0.0; d], 0.1, 0.0)
        .unwrap();
    let mut evaluator = Evaluator::Quadratic(Arc::clone(&problem));
    let view = coordinator.next_view();
    let out = coordinator.run_round(&view).unwrap();
    assert_eq!(out.collected, 8, "warm-up round populates the cache");
    assert_eq!(out.missing, 0);
    for _ in 0..30 {
        let view = coordinator.next_view();
        let out = coordinator.run_round(&view).unwrap();
        assert_eq!(out.collected, 7, "the silent worker misses the deadline");
        assert_eq!(out.missing, 1, "the straggler falls through the cache");
    }
    assert_eq!(coordinator.metrics.counter("gradients_missing"), 30);
    let (loss, _) = evaluator.evaluate(coordinator.params()).unwrap();
    assert!(
        loss.is_finite() && loss < 0.05,
        "training with one cached straggler must stay healthy: loss {loss}"
    );
    assert!(coordinator.params().iter().all(|v| v.is_finite()));
    coordinator.shutdown();
}
